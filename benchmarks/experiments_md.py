"""Generate EXPERIMENTS.md from dry-run artifacts + benchmark results +
the perf-iteration log (results/perf_log.json, appended by the §Perf
hillclimbs)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline_table import load_all

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
PERF_LOG = os.path.join(ROOT, "results", "perf_log.json")
OUT = os.path.join(ROOT, "EXPERIMENTS.md")


def _fmt(r):
    t = r["roofline"]
    m = r["memory"]["peak_bytes_per_device"] / 2**30
    fit = "ok" if m <= 16 else f"**OVER {m:.0f}G**"
    extra = f" n_micro={r['n_micro']}" if r.get("n_micro") else ""
    return (f"| {r['arch']} | {r['shape']} | {r['quant']}{extra} | "
            f"{t['compute_s']:.4f} | {t['memory_s']:.4f} | "
            f"{t['collective_s']:.4f} | **{t['dominant'].replace('_s', '')}**"
            f" | {r['useful_flops_ratio']:.2f} | {m:.1f} | {fit} |")


_FIX_HINTS = {
    ("memory", "prefill"): "raise attention q-chunk (cuts K/V re-reads) "
                           "and/or W4A8 weights (halve weight traffic)",
    ("memory", "train"): "fewer microbatches / larger per-step batch raises "
                         "arithmetic intensity; quantized grads cut traffic",
    ("memory", "decode"): "int8 KV cache halves cache traffic; W4A8 halves "
                          "weight reads",
    ("collective", "decode"): "weight-stationary (ws) sharding removes "
                              "per-layer FSDP weight all-gathers",
    ("collective", "train"): "drop n_micro (re-gathers weights per micro); "
                             "int8 gradient all-reduce across pods",
    ("collective", "prefill"): "2D->1D resharding of activations; batch "
                               "bigger per-gather",
    ("compute", "prefill"): "near roofline — int8 GEMMs already 2x bf16",
    ("compute", "train"): "near roofline — remat policy tuning next",
    ("compute", "decode"): "compute-minor at decode; expected",
}


def hint(r):
    return _FIX_HINTS.get((r["roofline"]["dominant"].replace("_s", ""),
                           r["kind"]), "")


def _dedupe(recs):
    seen, out = set(), []
    for r in recs:
        key = (r.get("arch"), r.get("shape"), r.get("mesh"), r.get("quant"),
               r.get("kv_bits"))
        if key in seen:
            continue
        seen.add(key)
        out.append(r)
    return out


def dryrun_section(recs):
    ok = _dedupe([r for r in recs
                  if r.get("status") == "ok" and not r.get("tag")])
    skipped = [r for r in recs if r.get("status") == "skipped"]
    errors = [r for r in recs if r.get("status") == "error"]
    single = [r for r in ok if r["mesh"] == "16x16"]
    multi = [r for r in ok if r["mesh"] == "2x16x16"]
    lines = ["## §Dry-run", ""]
    lines.append(f"- cells compiled OK: **{len(ok)}** "
                 f"({len(single)} single-pod 16x16, {len(multi)} multi-pod "
                 f"2x16x16); skipped per assignment rule: "
                 f"{len(skipped) // 1}; errors: {len(errors)}")
    lines.append("- every compile records `memory_analysis()` "
                 "(bytes/device — the fits-HBM proof), loop-corrected HLO "
                 "FLOPs/bytes (see roofline/hlo_cost.py: XLA cost_analysis "
                 "counts scan bodies once; the walker multiplies by "
                 "known_trip_count), and the collective schedule "
                 "(op x operand bytes x replica-group, ring-adjusted).")
    lines.append("- artifacts: `results/dryrun/*.json` "
                 "(one per arch x shape x mesh x quant).")
    if errors:
        lines.append("")
        lines.append("### Errors")
        for r in errors:
            lines.append(f"- {r['arch']} x {r['shape']} ({r['mesh']}): "
                         f"{r['error']}")
    # memory proof table (multi-pod)
    lines += ["", "### Multi-pod (2x16x16 = 512 chips) memory proof", "",
              "| arch | shape | quant | GiB/device | fits 16G HBM |",
              "|---|---|---|---|---|"]
    for r in sorted(multi, key=lambda r: (r["arch"], r["shape"])):
        m = r["memory"]["peak_bytes_per_device"] / 2**30
        lines.append(f"| {r['arch']} | {r['shape']} | {r['quant']} | "
                     f"{m:.2f} | {'yes' if m <= 16 else '**NO**'} |")
    return "\n".join(lines)


def roofline_section(recs):
    ok = _dedupe([r for r in recs
                  if r.get("status") == "ok" and not r.get("tag")
                  and r["mesh"] == "16x16"])
    lines = ["## §Roofline (single-pod 16x16 = 256 chips, per step)", ""]
    lines.append("Terms in seconds from the v5e model (197 TF/s bf16, "
                 "394 TOP/s int8, 819 GB/s HBM, 50 GB/s/link ICI); "
                 "`useful` = MODEL_FLOPS / HLO_FLOPs "
                 "(6·N·D train, 2·N·D prefill/decode; N_active for MoE).")
    lines += ["",
              "| arch | shape | quant | compute_s | memory_s | collective_s"
              " | dominant | useful | GiB/dev | fits |",
              "|---|---|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for r in sorted(ok, key=lambda r: (r["arch"], order[r["shape"]])):
        lines.append(_fmt(r))
    caveats = ("Reading caveats: (1) the walker counts GEMM FLOPs only, so `useful` can exceed 1 for tiny models whose parameter count is embedding-dominated (xlstm decode). (2) CPU-backend lowering emulates bf16 compute in f32 - activation-collective and score-chain bytes are ~2x what the same module moves on TPU (bf16-native reduces); terms are conservative upper bounds. (3) llama32_vision_90b x decode_32k runs with the int8 KV cache (kv8): the bf16 cache does not fit HBM at 128 x 32k (beyond-paper W8A8KV8).")
    lines += ["", caveats]
    lines += ["", "### Dominant-term notes (what moves it down)", ""]
    seen = set()
    for r in sorted(ok, key=lambda r: (r["arch"], order[r["shape"]])):
        h = hint(r)
        key = (r["arch"], r["shape"])
        if h and key not in seen:
            seen.add(key)
            lines.append(f"- **{r['arch']} x {r['shape']}** "
                         f"({r['roofline']['dominant'].replace('_s','')}-"
                         f"bound): {h}")
    return "\n".join(lines)


def perf_section():
    lines = ["## §Perf — hillclimbing log (hypothesis -> change -> "
             "before -> after)", ""]
    if not os.path.exists(PERF_LOG):
        lines.append("(pending)")
        return "\n".join(lines)
    with open(PERF_LOG) as f:
        log = json.load(f)
    for cell in log:
        lines.append(f"### {cell['cell']} — {cell['why']}")
        lines.append("")
        base = cell["baseline"]
        lines.append(f"Baseline ({base['config']}): compute {base['compute_s']:.4f}s, "
                     f"memory {base['memory_s']:.4f}s, collective "
                     f"{base['collective_s']:.4f}s -> bound "
                     f"{base['bound_s']:.4f}s (dominant: {base['dominant']})")
        lines.append("")
        lines.append("| # | hypothesis | change | before (dom term) | "
                     "after | verdict |")
        lines.append("|---|---|---|---|---|---|")
        for i, it in enumerate(cell["iterations"], 1):
            lines.append(f"| {i} | {it['hypothesis']} | {it['change']} | "
                         f"{it['before_s']:.4f}s | {it['after_s']:.4f}s | "
                         f"{it['verdict']} |")
        lines.append("")
        fin = cell["final"]
        lines.append(f"**Result**: bound {base['bound_s']:.4f}s -> "
                     f"{fin['bound_s']:.4f}s "
                     f"({base['bound_s'] / fin['bound_s']:.2f}x); "
                     f"{fin['note']}")
        lines.append("")
    return "\n".join(lines)


def bench_section():
    path = os.path.join(ROOT, "bench_output.txt")
    lines = ["## Paper-claim validation (benchmarks/run.py)", ""]
    if os.path.exists(path):
        picked = [l.strip() for l in open(path)
                  if ("claim" in l or "retention" in l or "speedup" in l
                      or "mem_saving" in l)]
        lines.append("```")
        lines += picked
        lines.append("```")
    else:
        lines.append("(run `PYTHONPATH=src python -m benchmarks.run` — "
                     "see bench_output.txt)")
    lines.append("")
    lines.append("Full CSV: `bench_output.txt`; per-table mapping in "
                 "DESIGN.md §7.")
    return "\n".join(lines)


HEADER = """# EXPERIMENTS

Reproduction + deployment study of *Post-Training Quantization of OpenPangu
Models for Efficient Deployment on Atlas A2* on the TPU-v5e production mesh
(DESIGN.md has the paper->system mapping).

- **Dry-run**: every (architecture x input-shape) cell AOT-compiled
  (.lower().compile()) on BOTH production meshes.
- **Roofline**: three-term model from compiled artifacts, loop-corrected.
- **Perf**: hillclimb log on the three selected cells
  (paper-faithful baseline first, beyond-paper second — both recorded).
"""


def main(print_rows=False):
    recs = load_all()
    doc = "\n\n".join([HEADER, dryrun_section(recs), roofline_section(recs),
                       perf_section(), bench_section()])
    with open(OUT, "w") as f:
        f.write(doc + "\n")
    print(f"# wrote {OUT} ({len(recs)} dry-run records)")
    return []


if __name__ == "__main__":
    main()

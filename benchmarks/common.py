"""Shared benchmark fixtures: a tiny *trained* openPangu-class model (PTQ on
converged weights, not random init), calibration stats, quantized variants,
and the synthetic-task accuracy metric.

"Task accuracy" for the synthetic Markov stream = fraction of generated
tokens that are valid successors of their predecessor under the generating
chain — a real correctness criterion for generations (the HumanEval
pass-rate analog; see DESIGN.md §7)."""
from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, reduced
from repro.core.quant import calibrate, preset, ptq
from repro.data import DataConfig, SyntheticLM, make_prompts
from repro.models import transformer
from repro.optim import adamw
from repro.serving import ServingEngine
from repro.train import trainer

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
BENCH_DIR = os.path.abspath(BENCH_DIR)
TRAIN_STEPS = 300
SEQ = 64
BATCH = 16


def _data(cfg, seed=0):
    return SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=SEQ, seed=seed))


_CACHE = {}


def trained_model(arch: str = "pangu_1b"):
    """Train (or restore) the tiny benchmark subject. Returns
    (cfg, params, data, stats)."""
    if arch in _CACHE:
        return _CACHE[arch]
    cfg = reduced(get_arch(arch), groups=2)
    data = _data(cfg)
    ck = Checkpointer(os.path.join(BENCH_DIR, f"model_{arch}"))
    ocfg = adamw.OptConfig(lr=3e-3, warmup_steps=30, total_steps=TRAIN_STEPS)
    state = trainer.init_state(jax.random.PRNGKey(0), cfg, ocfg)
    if ck.latest_step() == TRAIN_STEPS:
        state = ck.restore(state)
    else:
        step = jax.jit(trainer.make_train_step(cfg, ocfg, remat=False))
        t0 = time.time()
        for i in range(TRAIN_STEPS):
            state, m = step(state, data.batch(i, BATCH))
        print(f"# trained {arch} for {TRAIN_STEPS} steps in "
              f"{time.time() - t0:.0f}s; loss={float(m['loss']):.3f}")
        ck.save(TRAIN_STEPS, state, blocking=True)
    params = state.params
    stats = calibrate.collect_stats(
        params, data.batches(10_000, 8, BATCH), cfg)
    out = (cfg, params, data, stats)
    _CACHE[arch] = out
    return out


def outlier_model(arch: str = "pangu_1b", scale: float = 32.0):
    """The trained model pushed into the activation-outlier regime real LLMs
    exhibit (SmoothQuant reports ~100x channels): a fixed 1/8 of embedding
    channels scaled up, stats recalibrated. Fig 1 / Table 2's mechanism
    claims are evaluated here; the clean tiny model has no outliers."""
    key = ("outlier", arch)
    if key in _CACHE:
        return _CACHE[key]
    cfg, params, data, _ = trained_model(arch)
    import numpy as np
    emb = np.array(params["embed"]["w"], copy=True)
    rng = np.random.default_rng(11)
    idx = rng.choice(cfg.d_model, size=cfg.d_model // 8, replace=False)
    emb[:, idx] *= scale
    params = dict(params)
    params["embed"] = {"w": jnp.asarray(emb)}
    stats = calibrate.collect_stats(params, data.batches(10_000, 8, BATCH),
                                    cfg)
    out = (cfg, params, data, stats)
    _CACHE[key] = out
    return out


def undertrained_model(arch: str = "pangu_1b", steps: int = 60):
    """A weaker subject (the paper's 1B-vs-7B robustness contrast analog)."""
    key = ("under", arch, steps)
    if key in _CACHE:
        return _CACHE[key]
    cfg = reduced(get_arch(arch), groups=2)
    data = _data(cfg)
    ocfg = adamw.OptConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
    state = trainer.init_state(jax.random.PRNGKey(0), cfg, ocfg)
    step = jax.jit(trainer.make_train_step(cfg, ocfg, remat=False))
    for i in range(steps):
        state, _ = step(state, data.batch(i, BATCH))
    stats = calibrate.collect_stats(state.params,
                                    data.batches(10_000, 4, BATCH), cfg)
    out = (cfg, state.params, data, stats)
    _CACHE[key] = out
    return out


def quantized_variants(cfg, params, stats, names=("int8", "w4a8",
                                                  "w4a8-smooth",
                                                  "w4a8-hadamard")):
    out = {"fp16": (None, params)}
    for name in names:
        qcfg = preset(name)
        out[name] = (qcfg, ptq.quantize_model(params, cfg, qcfg, stats))
    return out


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def eval_logits(params, cfg, data, qcfg=None, n_batches=4, start=20_000):
    outs = []
    for i in range(n_batches):
        b = data.batch(start + i, BATCH)
        logits, _ = transformer.forward_train(
            params, b, cfg, qcfg=qcfg, impl="xla" if qcfg else None,
            remat=False)
        outs.append((logits, b["labels"]))
    return outs


def perplexity(pairs):
    tot, n = 0.0, 0
    for logits, labels in pairs:
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        tot += float(jnp.sum(nll))
        n += labels.size
    return float(np.exp(tot / n))


def agreement_and_kl(pairs_ref, pairs_q):
    agree, kl, n = 0.0, 0.0, 0
    for (lr, _), (lq, _) in zip(pairs_ref, pairs_q):
        agree += float(jnp.sum(jnp.argmax(lr, -1) == jnp.argmax(lq, -1)))
        p = jax.nn.softmax(lr, -1)
        kl += float(jnp.sum(p * (jax.nn.log_softmax(lr, -1)
                                 - jax.nn.log_softmax(lq, -1))))
        n += lr.shape[0] * lr.shape[1]
    return agree / n, kl / n


def successor_accuracy(data, prompts, generations):
    """Fraction of generated tokens that are valid Markov successors."""
    succ = np.asarray(data.succ)
    total, ok = 0, 0
    for p, g in zip(prompts, generations):
        seq = list(p) + list(g)
        for a, b in zip(seq[len(p) - 1:-1], seq[len(p):]):
            if a < succ.shape[0]:
                ok += int(b in succ[a])
                total += 1
    return ok / max(total, 1)


def engines_for(cfg, variants, kv_bits=16):
    return {name: ServingEngine(p, cfg, qcfg=q, impl="xla" if q else None,
                                kv_bits=kv_bits)
            for name, (q, p) in variants.items()}


def bench_prompts(cfg, n=16, prompt_len=12):
    return make_prompts(DataConfig(vocab=cfg.vocab, seq_len=SEQ), n,
                        prompt_len)


def row(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"

"""Figure 2 analog: CoT trace length per reasoning mode, FP16 vs INT8.

Paper claim tested: quantization has only a limited effect on output
length in most configurations (<= ~20% shift per mode)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.serving import cot


def main(print_rows=True):
    cfg, params, data, stats = common.trained_model()
    variants = common.quantized_variants(cfg, params, stats, names=("int8",))
    engines = common.engines_for(cfg, variants)
    # mixed prompt lengths so auto_think exercises both branches
    prompts = (common.bench_prompts(cfg, n=8, prompt_len=8)
               + common.bench_prompts(cfg, n=8, prompt_len=40))

    rows, lens = [], {}
    for name, eng in engines.items():
        study = eng.cot_study(prompts, max_new=32)
        for mode in cot.MODES:
            lens[(mode, name)] = study[mode]["mean_len"]
            rows.append(common.row(f"fig2/{mode}/{name}/mean_len", 0,
                                   f"{study[mode]['mean_len']:.2f}"))
    worst = max(abs(lens[(m, "int8")] - lens[(m, "fp16")])
                / max(lens[(m, "fp16")], 1e-9) for m in cot.MODES)
    rows.append(common.row("fig2/max_len_shift", 0, f"{worst * 100:.1f}%"))
    rows.append(common.row("fig2/claim_limited_effect", 0,
                           "PASS" if worst <= 0.25 else f"FAIL({worst:.2f})"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()

"""Continuous-batching serving benchmark: tokens/sec and KV bytes/token for
the fp16 vs int8 paged cache across batch sizes 1-32 on the pangu_1b config.

    PYTHONPATH=src python benchmarks/bench_serving.py [--full] [--max-new N]

Reports (and asserts, so the bench doubles as an acceptance gate):
  * int8 paged cache uses <= 55% of the fp16 pool's KV bytes/token
    (per-page per-head scales amortize the scale overhead to 4/page_size
    bytes per head; a per-token-scale layout would sit at ~56% for hd=32);
  * continuous batching at batch 8 delivers >= 2x the tokens/sec of the
    same engine run with a single slot (per-step weight-streaming and
    dispatch overhead amortize across the packed batch);
  * the Pallas paged-attention kernel (interpret mode — this host has no
    TPU) decodes the same tokens as the XLA gather path.

Throughput is measured on the jitted XLA paged path: interpret-mode Pallas
re-traces the kernel grid in Python and measures the interpreter, not the
serving engine. On a real Atlas-A2-class part the streaming kernel replaces
the gather; its correctness is what's gated here.
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import sys
import time

import jax
import numpy as np

if importlib.util.find_spec("repro") is None:       # script run w/o PYTHONPATH
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_arch, reduced            # noqa: E402
from repro.data import DataConfig, make_prompts        # noqa: E402
from repro.models import transformer                   # noqa: E402
from repro.serving import ContinuousBatchingEngine     # noqa: E402

PAGE = 16


def make_engine(params, cfg, *, kv_bits, max_batch, max_seq_len,
                paged_impl="xla"):
    return ContinuousBatchingEngine(
        params, cfg, kv_bits=kv_bits, page_size=PAGE, max_batch=max_batch,
        max_seq_len=max_seq_len, paged_impl=paged_impl)


def throughput(eng, prompts, max_new):
    eng.run(prompts[:1], max_new=4)            # warm the jit caches
    t0 = time.time()
    res = eng.run(prompts, max_new=max_new)
    dt = time.time() - t0
    toks = sum(len(t) for t in res.tokens)
    return toks / dt, res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pangu_1b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced, CPU-sized)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batches", type=int, nargs="*",
                    default=[1, 2, 4, 8, 16, 32])
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    max_seq_len = PAGE * -(-(args.prompt_len + args.max_new + 2) // PAGE)
    prompts = make_prompts(DataConfig(vocab=cfg.vocab, seq_len=64),
                           max(args.batches), args.prompt_len)

    # -- KV bytes/token: fp16 vs int8 pool (geometry, batch-independent) ----
    bpt = {}
    for kv_bits in (16, 8):
        eng = make_engine(params, cfg, kv_bits=kv_bits, max_batch=1,
                          max_seq_len=max_seq_len)
        bpt[kv_bits] = eng.kv_bytes_per_token()
    ratio = bpt[8] / bpt[16]
    print(f"# KV bytes/token: fp16={bpt[16]:.1f} int8={bpt[8]:.1f} "
          f"(ratio {ratio:.3f})")

    # -- pallas kernel (interpret) vs XLA gather: same tokens ---------------
    few = prompts[:2]
    r_xla = make_engine(params, cfg, kv_bits=8, max_batch=2,
                        max_seq_len=max_seq_len).run(few, max_new=8)
    r_pal = make_engine(params, cfg, kv_bits=8, max_batch=2,
                        max_seq_len=max_seq_len,
                        paged_impl="pallas_interpret").run(few, max_new=8)
    kernel_ok = r_xla.tokens == r_pal.tokens
    print(f"# pallas(interpret) == xla decode tokens: {kernel_ok}")

    # -- throughput sweep ---------------------------------------------------
    print(f"# {'batch':>5s} {'kv':>4s} {'tok/s':>8s} {'steps':>6s} "
          f"{'KV B/tok':>9s}")
    tput = {}
    for kv_bits in (16, 8):
        for b in args.batches:
            eng = make_engine(params, cfg, kv_bits=kv_bits, max_batch=b,
                              max_seq_len=max_seq_len)
            tps, res = throughput(eng, prompts[:max(b, 8)], args.max_new)
            tput[(kv_bits, b)] = tps
            print(f"  {b:5d} {kv_bits:4d} {tps:8.1f} {res.steps_run:6d} "
                  f"{eng.kv_bytes_per_token():9.1f}")

    ok = True
    if ratio > 0.55:
        ok = False
        print(f"FAIL: int8 KV bytes/token ratio {ratio:.3f} > 0.55")
    if (8, 8) in tput and (8, 1) in tput:
        speedup = tput[(8, 8)] / tput[(8, 1)]
        print(f"# continuous batch=8 vs single-slot speedup (int8 KV): "
              f"{speedup:.2f}x")
        if speedup < 2.0:
            ok = False
            print(f"FAIL: batch-8 speedup {speedup:.2f}x < 2x")
    else:
        print("# speedup check skipped (--batches does not include 1 and 8)")
    if not kernel_ok:
        ok = False
        print("FAIL: pallas kernel tokens diverge from XLA path")
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Continuous-batching serving benchmark: decode tokens/sec, batched
prefill tokens/sec, TTFT, compile counts, and KV bytes/token for the fp16
vs int8 vs packed-int4 paged cache on the pangu_1b config.

    PYTHONPATH=src python benchmarks/bench_serving.py [--full] [--smoke]

Reports (and asserts, so the bench doubles as an acceptance gate):
  * int8 paged cache uses <= 55% of the fp16 pool's KV bytes/token
    (per-page per-head scales amortize the scale overhead to 4/page_size
    bytes per head; a per-token-scale layout would sit at ~56% for hd=32);
  * packed-int4 pages (two nibbles per byte along head_dim) use <= 30% of
    the fp16 pool's KV bytes/token, and the int4 engine is functional
    end-to-end: chunked prefill + prefix caching + speculative decode on
    packed pages emit valid tokens on at most 3 steady-state programs,
    warm prefix hits replay bit-identical packed codes + scales, and
    speculative truncate is bit-identical to a direct write;
  * chunked batched prefill (the mixed-step path, fused quantize-on-write)
    delivers >= 1.5x the prefill tokens/sec of the legacy per-admission
    path at batch 8, without regressing steady-state decode-step latency
    by more than 10%;
  * compile counts stay bounded: the chunked engine runs on exactly two
    steady-state programs (mixed + decode, zero one-shot prefills); the
    legacy engine compiles at most one prefill program per distinct
    power-of-two page bucket;
  * continuous batching at batch 8 delivers >= 2x the tokens/sec of the
    same engine run with a single slot (skipped under --smoke);
  * the Pallas paged kernels (interpret mode — this host has no TPU)
    produce the same tokens as the XLA gather path;
  * refcounted prefix caching: on a batch-8 workload sharing a 6-page
    system prompt, a warm cache cuts mean TTFT >= 2x vs the cold first
    batch (hit rate >= 0.5 on re-submission) without regressing the
    decode-step latency floor by more than 5% vs a cache-off engine;
  * self-speculative decoding (n-gram drafting + batched verify): >= 1.3x
    decode tok/s over the non-speculative engine at batch 8 on a
    repetitive (n-gram-friendly) workload with bit-exact greedy outputs
    on bf16 pools, <= 5% decode tok/s regression on an adversarial
    (low-acceptance) workload with int8 pools, and at most 3 steady-state
    programs (mixed + decode + verify; still zero one-shot prefills).

The speculative workloads are fixed (seed, prompt-index) picks into
make_prompts under this file's reduced pangu_1b config and PRNGKey(0)
weights: the friendly set is the 8 lanes whose greedy bf16 continuations
loop earliest (most drafter-predictable), the adversarial set 8 lanes
whose continuations never repeat an n-gram. The 1.3x/bit-exact gate runs
on bf16 pools because int8 page scales are recomputed from full-page
content on every write — a vanilla decode re-rounds the page token by
token, so each position sees a slightly different effective cache than
one shared-K verify pass can reproduce; int8 friendly numbers are
reported (acceptance rate, speedup) but only the regression bound is
gated there.

--json PATH dumps every reported metric as a JSON document (CI uploads it
as an artifact so runs are comparable across commits) — including decode
tok/s, TTFT percentiles, and speculative acceptance rates.

Throughput is measured on the jitted XLA paged path: interpret-mode Pallas
re-traces the kernel grid in Python and measures the interpreter, not the
serving engine. On a real Atlas-A2-class part the streaming kernels replace
the gathers; their correctness is what's gated here.

--smoke runs the gates (bytes ratios, prefill speedup, decode latency,
compile counts, kernel parity, int4 functional) on CI-sized shapes and
skips the batch sweep; scripts/ci.sh runs it on every push. --kv-bits
selects the pool dtype the engine-level legs (kernel parity, chunked vs
legacy prefill, prefix caching) run under — the CI int4 leg passes
`--kv-bits 4` so the whole serving path is exercised on packed pages and
its metrics land in a separate artifact.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import os
import sys
import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

if importlib.util.find_spec("repro") is None:       # script run w/o PYTHONPATH
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_arch, reduced            # noqa: E402
from repro.data import DataConfig, make_prompts        # noqa: E402
from repro.models import transformer                   # noqa: E402
from repro.serving import ContinuousBatchingEngine     # noqa: E402
from repro.serving import kv_pool                      # noqa: E402

PAGE = 16
CHUNK_PAGES = 2

# speculative workloads: (make_prompts seed, prompt index) under
# DataConfig(vocab=cfg.vocab, seq_len=64), 8 prompts of 24 tokens per seed
# — see the module docstring for how these lanes were picked and why the
# bit-exact gate runs on bf16 pools
SPEC_FRIENDLY = [(23, 2), (18, 2), (17, 1), (3, 2),
                 (27, 2), (21, 6), (16, 2), (25, 7)]
SPEC_ADVERSARIAL = [(12, 0), (17, 4), (32, 3), (29, 0),
                    (8, 4), (31, 7), (3, 5), (12, 1)]
SPEC_PROMPT_LEN = 24
SPEC_MAX_NEW = 256
SPEC_SEQ_LEN = 320
SPEC_K = 8
# one page of a fixed token plus a ramp: loops immediately, so one warmup
# run compiles the verify program alongside mixed + decode
SPEC_WARM_PROMPT = [7] * 8 + list(range(16))


def make_engine(params, cfg, *, kv_bits, max_batch, max_seq_len,
                paged_impl="xla", prefill_mode="chunked",
                prefix_cache=False):
    # full token budget: every slot advances a chunk per mixed step — the
    # batched-prefill configuration the >= 1.5x gate measures
    return ContinuousBatchingEngine(
        params, cfg, kv_bits=kv_bits, page_size=PAGE, max_batch=max_batch,
        max_seq_len=max_seq_len, paged_impl=paged_impl,
        prefill_mode=prefill_mode, chunk_pages=CHUNK_PAGES,
        token_budget=max_batch * CHUNK_PAGES * PAGE,
        prefix_cache=prefix_cache)


def throughput(eng, prompts, max_new):
    eng.run(prompts[:1], max_new=4)            # warm the jit caches
    t0 = time.time()
    res = eng.run(prompts, max_new=max_new)
    dt = time.time() - t0
    toks = sum(len(t) for t in res.tokens)
    return toks / dt, res


def prefill_metrics(eng, prompts, max_new=8):
    """Drive one batch through the engine, splitting the wall clock into a
    prefill phase (submit -> every request has its first token) and a
    steady decode phase. Returns prefill tok/s, TTFT, decode-step latency."""
    rids = [eng.submit(p, max_new=max_new) for p in prompts]
    pending = set(rids)
    ttft = {}
    t0 = time.time()
    while pending:
        eng.step()
        now = time.time()
        done = {r for r in pending if eng._requests[r].out}
        for r in done:
            ttft[r] = now - t0
        pending -= done
    prefill_s = time.time() - t0
    n_prompt = sum(len(eng._requests[r].prompt) for r in rids)
    dts = []
    while not eng.sched.idle:
        s0 = time.time()
        eng.step()
        dts.append(time.time() - s0)
    return {"prefill_tok_s": n_prompt / prefill_s,
            "ttft_mean_ms": 1e3 * float(np.mean(list(ttft.values()))),
            "ttft_max_ms": 1e3 * float(np.max(list(ttft.values()))),
            "ttft_all_ms": [1e3 * t for t in ttft.values()],
            "decode_dts": dts}


def best_prefill(eng, prompts, reps=3, max_new=8):
    """Best-of-reps to shave scheduler noise off CI boxes; decode-step
    samples pool across reps and report the 10th-percentile floor (medians
    of ~30 samples at ~1 ms/step swing +-50% run to run; the floor is what
    a latency regression would move)."""
    eng.run(prompts[:1], max_new=2)            # warm every program
    runs = [prefill_metrics(eng, prompts, max_new=max_new)
            for _ in range(reps)]
    dts = [d for r in runs for d in r["decode_dts"]]
    ttfts = [t for r in runs for t in r["ttft_all_ms"]]
    return {"prefill_tok_s": max(r["prefill_tok_s"] for r in runs),
            "ttft_mean_ms": min(r["ttft_mean_ms"] for r in runs),
            "ttft_max_ms": min(r["ttft_max_ms"] for r in runs),
            "ttft_percentiles_ms": {
                f"p{q}": float(np.percentile(ttfts, q))
                for q in (50, 90, 99)},
            "decode_ms": (1e3 * float(np.percentile(dts, 10)) if dts
                          else float("nan"))}


def decode_floor(eng, prompts, max_new, reps=3):
    """Steady-state decode-step latency floor in ms: finish every prefill,
    then time each pure-decode step and take the min across reps. The min
    is the stable estimator here — p10/median of ~1 ms host-loop steps
    swing +-10% run to run, far above the 5% regression this gates."""
    best = float("inf")
    for _ in range(reps):
        rids = [eng.submit(p, max_new=max_new) for p in prompts]
        while any(not eng._requests[r].out for r in rids):
            eng.step()
        dts = []
        while not eng.sched.idle:
            t0 = time.perf_counter()
            eng.step()
            dts.append(time.perf_counter() - t0)
        best = min(best, min(dts))
    return 1e3 * best


def spec_prompts(cfg, keys):
    """Materialize a fixed speculative workload: prompt `i` of the 8-prompt
    batch make_prompts generates under `seed`, for each (seed, i) key."""
    out = []
    for seed, i in keys:
        ps = make_prompts(DataConfig(vocab=cfg.vocab, seq_len=64, seed=seed),
                          8, SPEC_PROMPT_LEN)
        out.append(list(ps[i]))
    return out


def spec_engine(params, cfg, *, kv_bits, k):
    return ContinuousBatchingEngine(
        params, cfg, kv_bits=kv_bits, page_size=PAGE, max_batch=8,
        max_seq_len=SPEC_SEQ_LEN, prefill_mode="chunked",
        chunk_pages=CHUNK_PAGES, token_budget=8 * CHUNK_PAGES * PAGE,
        prefix_cache=True, spec_decode=k)


def decode_tok_s_pair(eng_a, eng_b, prompts, max_new=SPEC_MAX_NEW, reps=4):
    """Best-of-reps end-to-end decode throughput for two engines on the
    same workload (decode-dominated: 24-token prompts, 256 generated
    tokens/lane). Reps alternate engines so a drifting box slows both
    sides alike — two back-to-back solo measurements decorrelate and can
    swing a throughput *ratio* by more than the 5% the adversarial gate
    bounds."""
    out = []
    for eng in (eng_a, eng_b):
        eng.run([SPEC_WARM_PROMPT], max_new=32)   # compiles verify too
        out.append([0.0, None])
    for _ in range(reps):
        for eng, slot in zip((eng_a, eng_b), out):
            t0 = time.time()
            r = eng.run(prompts, max_new=max_new)
            dt = time.time() - t0
            tps = sum(len(t) for t in r.tokens) / dt
            if tps > slot[0]:
                slot[0], slot[1] = tps, r
    return out[0][0], out[0][1], out[1][0], out[1][1]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pangu_1b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced, CPU-sized)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: gates only, no batch sweep")
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--batches", type=int, nargs="*", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all reported metrics to PATH as JSON")
    ap.add_argument("--kv-bits", type=int, choices=[4, 8, 16], default=8,
                    help="pool dtype for the engine-level legs (kernel "
                    "parity, chunked-vs-legacy prefill, prefix caching); "
                    "the bytes and int4 gates always run")
    args = ap.parse_args(argv)
    prompt_len = args.prompt_len or (48 if args.smoke else 16)
    max_new = args.max_new or (8 if args.smoke else 32)
    batches = args.batches or ([] if args.smoke else [1, 2, 4, 8, 16, 32])

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    max_seq_len = PAGE * -(-(prompt_len + max_new + 2) // PAGE)
    n_prompts = max(batches + [8])
    prompts = make_prompts(DataConfig(vocab=cfg.vocab, seq_len=64),
                           n_prompts, prompt_len)
    ok = True

    # -- KV bytes/token: fp16 vs int8 vs packed-int4 pool (geometry) --------
    bpt = {}
    for kv_bits in (16, 8, 4):
        eng = make_engine(params, cfg, kv_bits=kv_bits, max_batch=1,
                          max_seq_len=max_seq_len)
        bpt[kv_bits] = eng.kv_bytes_per_token()
    ratio = bpt[8] / bpt[16]
    ratio4 = bpt[4] / bpt[16]
    print(f"# KV bytes/token: fp16={bpt[16]:.1f} int8={bpt[8]:.1f} "
          f"int4={bpt[4]:.1f} (ratios {ratio:.3f} / {ratio4:.3f})")
    if ratio > 0.55:
        ok = False
        print(f"FAIL: int8 KV bytes/token ratio {ratio:.3f} > 0.55")
    if ratio4 > 0.30:
        ok = False
        print(f"FAIL: int4 KV bytes/token ratio {ratio4:.3f} > 0.30")

    # -- pallas kernels (interpret) vs XLA gather: same tokens --------------
    few = prompts[:2]
    r_xla = make_engine(params, cfg, kv_bits=args.kv_bits, max_batch=2,
                        max_seq_len=max_seq_len).run(few, max_new=8)
    r_pal = make_engine(params, cfg, kv_bits=args.kv_bits, max_batch=2,
                        max_seq_len=max_seq_len,
                        paged_impl="pallas_interpret").run(few, max_new=8)
    kernel_ok = r_xla.tokens == r_pal.tokens
    print(f"# pallas(interpret) == xla serving tokens: {kernel_ok}")
    if not kernel_ok:
        ok = False
        print("FAIL: pallas kernel tokens diverge from XLA path")

    # -- chunked vs legacy prefill at batch 8 -------------------------------
    b8 = prompts[:8]
    engines = {}
    for mode in ("chunked", "legacy"):
        engines[mode] = make_engine(params, cfg, kv_bits=args.kv_bits,
                                    max_batch=8, max_seq_len=max_seq_len,
                                    prefill_mode=mode)
    stats = {m: best_prefill(engines[m], b8, max_new=max_new)
             for m in engines}
    print(f"# {'mode':>8s} {'prefill tok/s':>13s} {'TTFT mean ms':>12s} "
          f"{'TTFT max ms':>11s} {'decode ms':>9s}")
    for m, s in stats.items():
        print(f"  {m:>8s} {s['prefill_tok_s']:13.1f} "
              f"{s['ttft_mean_ms']:12.1f} {s['ttft_max_ms']:11.1f} "
              f"{s['decode_ms']:9.2f}")
    speedup = stats["chunked"]["prefill_tok_s"] / \
        stats["legacy"]["prefill_tok_s"]
    lat = stats["chunked"]["decode_ms"] / stats["legacy"]["decode_ms"]
    print(f"# chunked vs legacy prefill speedup: {speedup:.2f}x "
          f"(decode-step latency ratio {lat:.2f})")
    if speedup < 1.5:
        ok = False
        print(f"FAIL: chunked prefill speedup {speedup:.2f}x < 1.5x")
    if not lat <= 1.10:
        ok = False
        print(f"FAIL: chunked decode-step latency ratio {lat:.2f} > 1.10")

    # -- compile counts -----------------------------------------------------
    cc_ch = engines["chunked"].compile_counts()
    cc_leg = engines["legacy"].compile_counts()
    print(f"# compile counts: chunked={cc_ch} legacy={cc_leg}")
    if cc_ch != {"prefill": 0, "mixed": 1, "decode": 1, "verify": 0}:
        ok = False
        print(f"FAIL: chunked engine is not two-program steady state: "
              f"{cc_ch}")
    # legacy buckets to powers of two: at most one program per distinct
    # pow2 page bucket across every prompt it prefilled
    need = {-(-(len(p) + 1) // PAGE) for p in b8} | {1}   # +directive; warmup
    buckets = {1 << (n - 1).bit_length() for n in need}
    if cc_leg["prefill"] > len(buckets):
        ok = False
        print(f"FAIL: legacy prefill compiled {cc_leg['prefill']} programs "
              f"> {len(buckets)} pow2 buckets")

    # -- prefix caching: shared 6-page system prompt at batch 8 -------------
    # warm-vs-cold TTFT on one cache-on engine: the jit warmup uses an
    # unrelated prompt so the first shared-prefix batch really runs cold,
    # then re-submissions hit the pages the first batch promoted.
    rng = np.random.default_rng(7)
    common = rng.integers(0, cfg.vocab, size=6 * PAGE).tolist()
    shared = [common + rng.integers(0, cfg.vocab, size=PAGE).tolist()
              for _ in range(8)]
    px_new = max(max_new, 16)                  # enough decode-step samples
    px_seq = PAGE * -(-(len(shared[0]) + px_new + 2) // PAGE)
    eng_on = make_engine(params, cfg, kv_bits=args.kv_bits, max_batch=8,
                         max_seq_len=px_seq, prefix_cache=True)
    eng_on.run(prompts[:1], max_new=2)         # jit warm, cache stays cold
    cold = prefill_metrics(eng_on, shared, max_new=px_new)
    h0 = eng_on.sched.prefix_hit_tokens
    p0 = eng_on.sched.prefix_prompt_tokens
    warm_runs = [prefill_metrics(eng_on, shared, max_new=px_new)
                 for _ in range(3)]
    hit_rate = (eng_on.sched.prefix_hit_tokens - h0) / \
        (eng_on.sched.prefix_prompt_tokens - p0)
    warm_ttft = min(r["ttft_mean_ms"] for r in warm_runs)
    ttft_speedup = cold["ttft_mean_ms"] / warm_ttft
    eng_off = make_engine(params, cfg, kv_bits=args.kv_bits, max_batch=8,
                          max_seq_len=px_seq)
    eng_off.run(prompts[:1], max_new=2)
    off_floor = decode_floor(eng_off, shared, max_new=px_new)
    on_floor = decode_floor(eng_on, shared, max_new=px_new)
    px_lat = on_floor / off_floor
    print(f"# prefix cache: cold TTFT {cold['ttft_mean_ms']:.1f} ms, "
          f"warm TTFT {warm_ttft:.1f} ms ({ttft_speedup:.2f}x), "
          f"warm hit rate {hit_rate:.2f}, decode floor on/off "
          f"{on_floor:.2f}/{off_floor:.2f} ms (ratio {px_lat:.2f})")
    if ttft_speedup < 2.0:
        ok = False
        print(f"FAIL: warm-cache TTFT speedup {ttft_speedup:.2f}x < 2x")
    if hit_rate < 0.5:
        ok = False
        print(f"FAIL: warm hit rate {hit_rate:.2f} < 0.5")
    if not px_lat <= 1.05:
        ok = False
        print(f"FAIL: prefix-cache decode-step latency ratio "
              f"{px_lat:.2f} > 1.05")
    px_stats = eng_on.prefix_cache_stats()

    # -- packed-int4 pool: functional + bit-exactness gates -----------------
    # e2e: chunked prefill + prefix caching + speculative decode on packed
    # pages, still within the 3-program steady state
    eng4 = spec_engine(params, cfg, kv_bits=4, k=SPEC_K)
    friendly = spec_prompts(cfg, SPEC_FRIENDLY)
    r4 = eng4.run(friendly, max_new=32)
    int4_tokens_ok = (all(len(t) == 32 for t in r4.tokens) and
                      all(0 <= tok < cfg.vocab
                          for t in r4.tokens for tok in t))
    cc4 = eng4.compile_counts()
    int4_programs_ok = (cc4["prefill"] == 0 and sum(cc4.values()) <= 3)
    # warm prefix hits must map the exact packed codes + scales the cold
    # pass wrote — never requantize or rewrite a shared page
    eng4.run(shared, max_new=8)
    cached = sorted(eng4.sched.cache._by_hash.values())
    before = jax.device_get(eng4.pools)
    h4 = eng4.sched.prefix_hit_tokens
    eng4.run(shared, max_new=8)
    after = jax.device_get(eng4.pools)
    int4_replay_ok = bool(cached) and \
        eng4.sched.prefix_hit_tokens > h4 and all(
            np.array_equal(before[blk][leaf][:, cached],
                           after[blk][leaf][:, cached])
            for blk in before for leaf in ("k", "v", "k_s", "v_s"))
    # speculative rollback: truncate == direct write of the accepted
    # prefix, bit-exact on packed nibbles and scales (page-exact rollback)
    geom = SimpleNamespace(n_kv_heads=2, hd=4)
    pool4 = kv_pool.init_pool(geom, 8, 4, kv_bits=4)
    rng4 = np.random.default_rng(3)
    rows = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    hist = jnp.asarray(rng4.normal(size=(2, 5, 2, 4)), jnp.float32)
    start = jnp.asarray([3, 1], jnp.int32)
    pool4 = kv_pool.write_chunk(pool4, hist, hist, rows,
                                jnp.zeros(2, jnp.int32), start)
    kw = jnp.asarray(rng4.normal(size=(2, 5, 2, 4)), jnp.float32)
    vw = jnp.asarray(rng4.normal(size=(2, 5, 2, 4)), jnp.float32)
    n_keep = jnp.asarray([2, 4], jnp.int32)
    snap = {leaf: pool4[leaf][rows] for leaf in pool4}
    pfull = kv_pool.write_chunk(pool4, kw, vw, rows, start,
                                jnp.full(2, 5, jnp.int32))
    rolled = kv_pool.truncate(pfull, rows, snap, kw, vw, start, n_keep)
    direct = kv_pool.write_chunk(pool4, kw, vw, rows, start, n_keep)
    int4_trunc_ok = all(np.array_equal(np.asarray(rolled[leaf]),
                                       np.asarray(direct[leaf]))
                        for leaf in pool4)
    print(f"# int4 pool: e2e tokens {int4_tokens_ok}, programs {cc4} "
          f"(<=3 {int4_programs_ok}), prefix replay bit-exact "
          f"{int4_replay_ok}, truncate bit-exact {int4_trunc_ok}")
    for cond, msg in ((int4_tokens_ok, "int4 engine emitted invalid tokens"),
                      (int4_programs_ok,
                       f"int4 engine exceeds 3 steady-state programs: "
                       f"{cc4}"),
                      (int4_replay_ok,
                       "int4 warm prefix hits rewrote packed pages"),
                      (int4_trunc_ok,
                       "int4 truncate differs from direct write")):
        if not cond:
            ok = False
            print(f"FAIL: {msg}")

    # -- speculative decoding at batch 8 ------------------------------------
    adversarial = spec_prompts(cfg, SPEC_ADVERSARIAL)
    spec = {"k": SPEC_K, "decode_tok_s": {}, "acceptance_rate": {}}
    for kv_bits in (16, 8):
        tag = "bf16" if kv_bits == 16 else "int8"
        van = spec_engine(params, cfg, kv_bits=kv_bits, k=0)
        sp = spec_engine(params, cfg, kv_bits=kv_bits, k=SPEC_K)
        v_f, rv, s_f, rs = decode_tok_s_pair(van, sp, friendly)
        spec["decode_tok_s"][f"vanilla_{tag}"] = v_f
        spec["decode_tok_s"][f"spec_{tag}"] = s_f
        spec[f"friendly_speedup_{tag}"] = s_f / v_f
        spec["acceptance_rate"][tag] = sp.spec_stats()["acceptance_rate"]
        if kv_bits == 16:
            spec["bit_exact_greedy_bf16"] = all(
                list(a) == list(b) for a, b in zip(rv.tokens, rs.tokens))
        else:
            v_a, _, s_a, _ = decode_tok_s_pair(van, sp, adversarial)
            spec["decode_tok_s"]["vanilla_int8_adversarial"] = v_a
            spec["decode_tok_s"]["spec_int8_adversarial"] = s_a
            spec["adversarial_ratio_int8"] = s_a / v_a
            spec["compile_counts"] = sp.compile_counts()
    print(f"# speculative (k={SPEC_K}): friendly bf16 "
          f"{spec['decode_tok_s']['vanilla_bf16']:.0f} -> "
          f"{spec['decode_tok_s']['spec_bf16']:.0f} tok/s "
          f"({spec['friendly_speedup_bf16']:.2f}x, acc "
          f"{spec['acceptance_rate']['bf16']:.2f}, bit-exact "
          f"{spec['bit_exact_greedy_bf16']}); friendly int8 "
          f"{spec['friendly_speedup_int8']:.2f}x (acc "
          f"{spec['acceptance_rate']['int8']:.2f}); adversarial int8 "
          f"{spec['adversarial_ratio_int8']:.2f}x; compile "
          f"{spec['compile_counts']}")
    if spec["friendly_speedup_bf16"] < 1.3:
        ok = False
        print(f"FAIL: speculative friendly speedup "
              f"{spec['friendly_speedup_bf16']:.2f}x < 1.3x")
    if not spec["bit_exact_greedy_bf16"]:
        ok = False
        print("FAIL: speculative greedy tokens diverge from vanilla (bf16)")
    if spec["adversarial_ratio_int8"] < 0.95:
        ok = False
        print(f"FAIL: speculative adversarial ratio "
              f"{spec['adversarial_ratio_int8']:.2f}x < 0.95x")
    cc_spec = spec["compile_counts"]
    if cc_spec["prefill"] + cc_spec["mixed"] + cc_spec["decode"] + \
            cc_spec["verify"] > 3:
        ok = False
        print(f"FAIL: speculative engine exceeds 3 steady-state programs: "
              f"{cc_spec}")

    # -- throughput sweep ---------------------------------------------------
    tput = {}
    if batches:
        print(f"# {'batch':>5s} {'kv':>4s} {'tok/s':>8s} {'steps':>6s} "
              f"{'KV B/tok':>9s}")
        for kv_bits in (16, 8):
            for b in batches:
                eng = make_engine(params, cfg, kv_bits=kv_bits, max_batch=b,
                                  max_seq_len=max_seq_len)
                tps, res = throughput(eng, prompts[:max(b, 8)], max_new)
                tput[(kv_bits, b)] = tps
                print(f"  {b:5d} {kv_bits:4d} {tps:8.1f} "
                      f"{res.steps_run + res.mixed_steps:6d} "
                      f"{eng.kv_bytes_per_token():9.1f}")
    if (8, 8) in tput and (8, 1) in tput:
        sp = tput[(8, 8)] / tput[(8, 1)]
        print(f"# continuous batch=8 vs single-slot speedup (int8 KV): "
              f"{sp:.2f}x")
        if sp < 2.0:
            ok = False
            print(f"FAIL: batch-8 speedup {sp:.2f}x < 2x")
    elif batches:
        print("# speedup check skipped (--batches does not include 1 and 8)")

    print("PASS" if ok else "FAIL")
    if args.json:
        doc = {
            "config": {"arch": args.arch, "full": args.full,
                       "smoke": args.smoke, "page_size": PAGE,
                       "chunk_pages": CHUNK_PAGES, "kv_bits": args.kv_bits,
                       "prompt_len": prompt_len, "max_new": max_new},
            "kv_bytes_per_token": {str(k): v for k, v in bpt.items()},
            "kv_bytes_ratio": ratio,
            "kv_bytes_ratio_int4": ratio4,
            "int4": {"tokens_ok": int4_tokens_ok,
                     "compile_counts": cc4,
                     "programs_ok": int4_programs_ok,
                     "prefix_replay_bitexact": int4_replay_ok,
                     "truncate_bitexact": int4_trunc_ok},
            "kernel_parity": kernel_ok,
            "prefill": {m: {k: v for k, v in s.items() if k != "decode_dts"}
                        for m, s in stats.items()},
            "chunked_prefill_speedup": speedup,
            "chunked_decode_latency_ratio": lat,
            "compile_counts": {"chunked": cc_ch, "legacy": cc_leg},
            "prefix_cache": {
                "cold_ttft_mean_ms": cold["ttft_mean_ms"],
                "warm_ttft_mean_ms": warm_ttft,
                "ttft_speedup": ttft_speedup,
                "warm_hit_rate": hit_rate,
                "decode_floor_on_ms": on_floor,
                "decode_floor_off_ms": off_floor,
                "decode_latency_ratio": px_lat,
                "engine_stats": px_stats,
            },
            "speculative": spec,
            "throughput_tok_s": {f"kv{k}_b{b}": v
                                 for (k, b), v in tput.items()},
            "pass": ok,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# metrics written to {args.json}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

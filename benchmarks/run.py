"""Benchmark harness — one entry per paper table/figure (+ the roofline
aggregate). Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (fig1_distributions, fig2_cot_length,
                            fig4_repetition, roofline_table, table1_fidelity,
                            table2_w4a8, table3_efficiency)
    print("name,us_per_call,derived")
    failures = 0
    for mod in (table1_fidelity, table2_w4a8, table3_efficiency,
                fig1_distributions, fig2_cot_length, fig4_repetition,
                roofline_table):
        t0 = time.time()
        try:
            mod.main(print_rows=True)
            print(f"bench/{mod.__name__.split('.')[-1]}/wall_s,0,"
                  f"{time.time() - t0:.1f}")
        except Exception as e:  # keep going; report at the end
            failures += 1
            print(f"bench/{mod.__name__.split('.')[-1]}/ERROR,0,"
                  f"{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Table 3 analog: prefill latency + memory, INT8/W4A8 vs FP16, batch 2-32.

The paper measures wall-clock on an Atlas A2 server; this container is
CPU-only, so deployment numbers are roofline bounds on an 8-chip v5e mesh
(the Atlas-A2-server analog). Two execution models are reported, which is
itself the paper's §3.1 contribution claim:

  * fused   — the deployment path: quantize/smooth/GEMM/dequant fused in
              the Pallas kernels (like the paper's CATLASS integration):
              analytic roofline (int8 MXU peak, int8 weight traffic, no
              intermediate format-conversion round-trips);
  * unfused — the "non-optimized baseline": the XLA-lowered op-by-op int8
              path, costed from the compiled HLO (loop-aware walker). Its
              extra quant/dequant memory passes ERASE the int8 advantage —
              reproducing why the paper needed the hardware-aware framework.

Paper claims tested: fused-INT8 prefill speedup in the 1.2-2x band that
grows/holds with batch; memory saving 13-40%; unfused loses the advantage.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

RESULT = os.path.join(os.path.dirname(__file__), "..", "results",
                      "table3.json")
BATCHES = (2, 4, 8, 16, 32)
SEQ = 1024
ARCH = "pangu-1b"          # the paper's 1B subject (proxy config)
N_CHIPS = 8


def _analytic_fused(cfg, b, quant):
    """Roofline terms for the fused-kernel deployment path (per 8-chip
    server): weights streamed once per prefill at their storage width,
    activations touched ~3x per layer at bf16, attention bf16."""
    from repro.roofline import analysis, hw
    n = cfg.param_count()
    tokens = b * SEQ
    mf = analysis.model_flops(cfg, "prefill", SEQ, b)
    lin = mf["linear_fwd_flops"]
    attn = mf["attn_flops"]
    if quant == "fp16":
        compute = (lin + attn) / hw.PEAK_BF16
        w_bytes = 2 * n
    else:
        compute = lin / hw.PEAK_INT8 + attn / hw.PEAK_BF16
        w_bytes = n if quant == "int8" else n // 2
    act_bytes = tokens * cfg.d_model * cfg.n_layers * 3 * 2
    kv_bytes = tokens * cfg.n_kv_heads * cfg.hd * 2 * cfg.n_layers * 2
    attn_bytes = attn // (2 * cfg.hd) * 2          # K/V streamed per q-block
    mem = (w_bytes + act_bytes + kv_bytes + attn_bytes) / hw.HBM_BW
    return {"compute_s": compute / N_CHIPS, "memory_s": mem / N_CHIPS,
            "latency_ms": max(compute, mem) / N_CHIPS * 1e3,
            "weight_gb": w_bytes / 2**30}


def _subprocess_main():
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={N_CHIPS}"
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.core.quant import preset, ptq
    from repro.models import transformer
    from repro.roofline import analysis, hlo_cost
    from repro.sharding import rules

    cfg = get_arch(ARCH)
    mesh = jax.make_mesh((1, N_CHIPS), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    out = {}
    for quant in ("fp16", "int8", "w4a8"):
        qcfg = preset(quant)
        pshapes = jax.eval_shape(
            lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
        if qcfg:
            pshapes = ptq.quantized_param_shapes(pshapes, cfg, qcfg)
        for b in BATCHES:
            batch = {"tokens": jax.ShapeDtypeStruct((b, SEQ), jnp.int32)}
            with mesh:
                def fn(params, batch):
                    return transformer.prefill(params, batch, cfg,
                                               max_len=SEQ, qcfg=qcfg,
                                               impl="xla")
                p_sh = rules.tree_shardings(mesh, pshapes, "param")
                b_sh = rules.batch_shardings(mesh, batch)
                comp = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(
                    pshapes, batch).compile()
            walk = hlo_cost.analyze(comp.as_text())
            mf = analysis.model_flops(cfg, "prefill", SEQ, b)
            int8_fl = mf["linear_fwd_flops"] if quant != "fp16" else 0.0
            terms = analysis.roofline_terms(
                hlo_flops_per_dev=walk["flops"],
                hlo_bytes_per_dev=walk["bytes"],
                link_bytes_per_dev=float(
                    walk["collectives"]["total_link_bytes"]),
                n_chips=N_CHIPS, int8_linear_flops_global=int8_fl)
            mem = comp.memory_analysis()
            peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
            fused = _analytic_fused(cfg, b, quant)
            out[f"{quant}/bs{b}"] = {
                "unfused_latency_ms": terms["step_s_lower_bound"] * 1e3,
                "fused_latency_ms": fused["latency_ms"],
                "fused_compute_ms": fused["compute_s"] * 1e3,
                "fused_memory_ms": fused["memory_s"] * 1e3,
                "mem_gb": peak * N_CHIPS / 2**30,   # whole server
                "dominant": terms["dominant"],
            }
            print(f"# {quant} bs={b}: {out[f'{quant}/bs{b}']}",
                  file=sys.stderr)
    os.makedirs(os.path.dirname(RESULT), exist_ok=True)
    with open(RESULT, "w") as f:
        json.dump(out, f, indent=1)


def main(print_rows=True):
    if not os.path.exists(RESULT):
        r = subprocess.run([sys.executable, __file__, "--subprocess"],
                           env={**os.environ,
                                "PYTHONPATH": os.environ.get("PYTHONPATH",
                                                             "src")},
                           capture_output=True, text=True)
        if r.returncode != 0:
            print(r.stderr[-2000:], file=sys.stderr)
            raise RuntimeError("table3 subprocess failed")
    with open(RESULT) as f:
        data = json.load(f)
    from benchmarks.common import row
    rows = []
    sp_fused, sp_unfused = {}, {}
    for b in BATCHES:
        fp = data[f"fp16/bs{b}"]
        i8 = data[f"int8/bs{b}"]
        w4 = data[f"w4a8/bs{b}"]
        sp_fused[b] = fp["fused_latency_ms"] / i8["fused_latency_ms"]
        sp_unfused[b] = fp["unfused_latency_ms"] / i8["unfused_latency_ms"]
        mem_save = 1 - i8["mem_gb"] / fp["mem_gb"]
        rows.append(row(f"table3/bs{b}/fp16_fused", fp["fused_latency_ms"]
                        * 1e3, f"{fp['mem_gb']:.2f}GB"))
        rows.append(row(f"table3/bs{b}/int8_fused", i8["fused_latency_ms"]
                        * 1e3, f"{i8['mem_gb']:.2f}GB"))
        rows.append(row(f"table3/bs{b}/w4a8_fused", w4["fused_latency_ms"]
                        * 1e3, f"{w4['mem_gb']:.2f}GB"))
        rows.append(row(f"table3/bs{b}/int8_speedup_fused", 0,
                        f"{sp_fused[b]:.2f}x"))
        rows.append(row(f"table3/bs{b}/int8_speedup_unfused", 0,
                        f"{sp_unfused[b]:.2f}x"))
        rows.append(row(f"table3/bs{b}/int8_mem_saving", 0,
                        f"{mem_save * 100:.1f}%"))
    rows.append(row("table3/claim_fused_speedup_1p2_to_2x", 0,
                    "PASS" if all(1.2 <= sp_fused[b] <= 2.2
                                  for b in BATCHES) else
                    f"CHECK({[round(sp_fused[b], 2) for b in BATCHES]})"))
    rows.append(row("table3/claim_mem_saving_13_to_40pct", 0,
                    "PASS" if all(0.10 <= (1 - data[f'int8/bs{b}']['mem_gb']
                                           / data[f'fp16/bs{b}']['mem_gb'])
                                  <= 0.45 for b in BATCHES) else "CHECK"))
    rows.append(row("table3/claim_unfused_loses_advantage", 0,
                    "PASS" if sp_unfused[32] < sp_fused[32] else "FAIL"))
    if print_rows:
        for r_ in rows:
            print(r_)
    return rows


if __name__ == "__main__":
    if "--subprocess" in sys.argv:
        _subprocess_main()
    else:
        main()

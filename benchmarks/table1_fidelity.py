"""Table 1 analog: FP16 vs INT8 accuracy across the three CoT modes.

Paper claim tested: INT8 preserves >= 90% of FP16 accuracy in every
reasoning mode (openPangu 1B/7B on HumanEval/MBPP -> tiny-trained
openPangu-class model on the synthetic successor task)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.serving import cot


def main(print_rows=True):
    cfg, params, data, stats = common.trained_model()
    variants = common.quantized_variants(cfg, params, stats, names=("int8",))
    engines = common.engines_for(cfg, variants)
    prompts = common.bench_prompts(cfg)

    # logit-level fidelity
    ref = common.eval_logits(params, cfg, data)
    ppl_fp = common.perplexity(ref)
    q = common.eval_logits(variants["int8"][1], cfg, data,
                           qcfg=variants["int8"][0])
    ppl_q = common.perplexity(q)
    top1, kl = common.agreement_and_kl(ref, q)

    rows = []
    accs = {}
    for mode in cot.MODES:
        for name in ("fp16", "int8"):
            t0 = time.time()
            res = engines[name].generate(prompts, max_new=24, mode=mode)
            us = (time.time() - t0) / len(prompts) * 1e6
            acc = common.successor_accuracy(data, prompts, res.tokens)
            accs[(mode, name)] = acc
            rows.append(common.row(f"table1/{mode}/{name}/task_acc", us,
                                   f"{acc:.4f}"))
    retention = min(accs[(m, "int8")] / max(accs[(m, "fp16")], 1e-9)
                    for m in cot.MODES)
    rows.append(common.row("table1/ppl_fp16", 0, f"{ppl_fp:.3f}"))
    rows.append(common.row("table1/ppl_int8", 0, f"{ppl_q:.3f}"))
    rows.append(common.row("table1/top1_agreement", 0, f"{top1:.4f}"))
    rows.append(common.row("table1/mean_kl", 0, f"{kl:.5f}"))
    rows.append(common.row("table1/min_mode_retention", 0,
                           f"{retention:.3f}"))
    rows.append(common.row(
        "table1/claim_int8_ge90pct", 0,
        "PASS" if retention >= 0.90 else f"FAIL({retention:.2f})"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()

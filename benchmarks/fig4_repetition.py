"""Figure 4 analog: repetitive-generation failure analysis.

Paper findings mirrored onto measurable analogs:
  * the weaker subject repeats far more than the stronger one
    (paper: 1B-FP16 up to 34% vs 7B < 2.5%)  ->  undertrained vs trained
    tiny model under temperature sampling;
  * INT8 does not increase repetition (paper: it *suppresses* it in 1B).

Note on the accuracy link (paper: repetitive 18.2% vs non-repetitive
87.4%): on the synthetic Markov task cyclic generations are *valid*
successors, so that correlation does not transfer; reported for
completeness, claim marked N/A (see DESIGN.md §7 mapping note).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.serving import cot


def _rates(cfg, params, stats, data, prompts):
    """Greedy decoding: a deterministic next-token map enters a cycle once
    any state repeats — the degenerate-generation analog; weaker models
    collapse to short cycles sooner."""
    variants = common.quantized_variants(cfg, params, stats, names=("int8",))
    engines = common.engines_for(cfg, variants)
    out = {}
    for name, eng in engines.items():
        per_mode = {}
        for mode in cot.MODES:
            res = eng.generate(prompts, max_new=64, mode=mode,
                               sampler="greedy")
            per_mode[mode] = cot.repetition_rate(res.tokens)
        out[name] = per_mode
    return out


def main(print_rows=True):
    rows = []
    cfg_t, params_t, data, stats_t = common.trained_model()
    cfg_u, params_u, _, stats_u = common.undertrained_model()
    prompts = common.bench_prompts(cfg_t, n=24, prompt_len=10)

    strong = _rates(cfg_t, params_t, stats_t, data, prompts)
    weak = _rates(cfg_u, params_u, stats_u, data, prompts)
    for label, rates in (("strong", strong), ("weak", weak)):
        for name, per_mode in rates.items():
            for mode, r in per_mode.items():
                rows.append(common.row(
                    f"fig4/{label}/{mode}/{name}/repetition_rate", 0,
                    f"{r:.3f}"))
    mean_w = np.mean([weak[n][m] for n in weak for m in weak[n]])
    mean_s = np.mean([strong[n][m] for n in strong for m in strong[n]])
    rows.append(common.row("fig4/mean_weak_vs_strong", 0,
                           f"{mean_w:.3f} vs {mean_s:.3f}"))
    if mean_w == 0.0 and mean_s == 0.0:
        rows.append(common.row("fig4/claim_weak_model_repeats_more", 0,
                               "N/A(no repetition surfaced at this scale)"))
    else:
        rows.append(common.row(
            "fig4/claim_weak_model_repeats_more", 0,
            "PASS" if mean_w >= mean_s else
            f"FAIL({mean_w:.3f}<{mean_s:.3f})"))
    int8_delta = np.mean([weak["int8"][m] - weak["fp16"][m]
                          for m in cot.MODES])
    rows.append(common.row(
        "fig4/claim_int8_not_worse_on_weak", 0,
        "PASS" if int8_delta <= 0.10 else f"FAIL({int8_delta:+.3f})"))
    rows.append(common.row(
        "fig4/accuracy_link", 0,
        "N/A-on-markov-task(cycles are valid successors; see DESIGN.md S7)"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()

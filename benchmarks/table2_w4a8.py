"""Table 2 analog: W4A8 configurations (baseline / SmoothQuant / Hadamard)
vs FP16 on the trained model.

Paper claims tested: (1) W4A8 degrades clearly vs INT8/FP16; (2) the
calibration-aware variants recover accuracy vs baseline W4A8 (Table 2's
ordering), measured at logit level (KL / top-1 / ppl) and on the task."""
from __future__ import annotations

import time

from benchmarks import common


def main(print_rows=True):
    cfg, params, data, stats = common.trained_model()
    variants = common.quantized_variants(cfg, params, stats)
    engines = common.engines_for(cfg, variants)
    prompts = common.bench_prompts(cfg)

    ref = common.eval_logits(params, cfg, data)
    rows = [common.row("table2/fp16/ppl", 0,
                       f"{common.perplexity(ref):.3f}")]
    kls = {}
    for name in ("int8", "w4a8", "w4a8-smooth", "w4a8-hadamard"):
        qcfg, qparams = variants[name]
        t0 = time.time()
        pairs = common.eval_logits(qparams, cfg, data, qcfg=qcfg)
        us = (time.time() - t0) / 4 * 1e6
        top1, kl = common.agreement_and_kl(ref, pairs)
        kls[name] = kl
        res = engines[name].generate(prompts, max_new=24, mode="slow_think")
        acc = common.successor_accuracy(data, prompts, res.tokens)
        rows.append(common.row(f"table2/{name}/ppl", us,
                               f"{common.perplexity(pairs):.3f}"))
        rows.append(common.row(f"table2/{name}/top1", 0, f"{top1:.4f}"))
        rows.append(common.row(f"table2/{name}/kl", 0, f"{kl:.5f}"))
        rows.append(common.row(f"table2/{name}/task_acc", 0, f"{acc:.4f}"))
    rows.append(common.row(
        "table2/claim_w4a8_degrades_vs_int8", 0,
        "PASS" if kls["w4a8"] > 2 * kls["int8"] else "FAIL"))
    rows.append(common.row(
        "table2/clean_model_scheme_deltas", 0,
        f"within-noise({kls['w4a8-smooth']:.4f}/{kls['w4a8-hadamard']:.4f}"
        f" vs {kls['w4a8']:.4f}) — no outlier channels in the tiny subject"))

    # Outlier regime (the activation distribution Table 2's ordering rests
    # on — see Fig. 1): smooth/hadamard must recover vs baseline W4A8.
    cfg_o, params_o, data_o, stats_o = common.outlier_model()
    variants_o = common.quantized_variants(cfg_o, params_o, stats_o,
                                           names=("w4a8", "w4a8-smooth",
                                                  "w4a8-hadamard"))
    ref_o = common.eval_logits(params_o, cfg_o, data_o)
    kls_o = {}
    for name in ("w4a8", "w4a8-smooth", "w4a8-hadamard"):
        qcfg, qparams = variants_o[name]
        pairs = common.eval_logits(qparams, cfg_o, data_o, qcfg=qcfg)
        _, kls_o[name] = common.agreement_and_kl(ref_o, pairs)
        rows.append(common.row(f"table2/outlier/{name}/kl", 0,
                               f"{kls_o[name]:.5f}"))
    best = min(kls_o["w4a8-smooth"], kls_o["w4a8-hadamard"])
    rows.append(common.row(
        "table2/claim_calibration_aware_recovers", 0,
        "PASS" if best < kls_o["w4a8"] else
        f"FAIL({kls_o['w4a8-smooth']:.4f},{kls_o['w4a8-hadamard']:.4f}"
        f" vs {kls_o['w4a8']:.4f})"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()

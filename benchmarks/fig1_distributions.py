"""Figure 1 analog: channel-wise |x| distributions under the W4A8
preprocessing variants. The paper shows baseline activations are heavy-
tailed with large outliers while SmoothQuant / Hadamard flatten them; we
report max/mean ratio and excess kurtosis of the per-channel absmax at the
first attention quant site of the trained model."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core.quant import smooth as sm
from repro.core.quant.hadamard import block_hadamard_matmul
from repro.models.layers import rms_norm


def _stats(x):
    am = np.max(np.abs(np.asarray(x, np.float32)), axis=0)
    mm = float(am.max() / max(am.mean(), 1e-9))
    c = am - am.mean()
    kurt = float(np.mean(c ** 4) / max(np.mean(c ** 2) ** 2, 1e-12) - 3.0)
    return mm, kurt


def main(print_rows=True):
    cfg, params, data, stats = common.outlier_model()
    batch = data.batch(30_000, common.BATCH)
    x = params["embed"]["w"][batch["tokens"]].astype(jnp.float32)
    x = rms_norm(x, params["blocks"]["0"]["ln1"]["g"][0],
                 cfg.norm_eps).reshape(-1, cfg.d_model)
    w = params["blocks"]["0"]["attn"]["wqkv"]["w"][0]
    s = sm.smooth_scales(jnp.asarray(stats["0/attn_in"][0]),
                         jnp.max(jnp.abs(w), axis=1))

    rows = []
    for name, t in (("baseline", x), ("smooth", x / s),
                    ("hadamard", block_hadamard_matmul(x, 128))):
        mm, kurt = _stats(t)
        rows.append(common.row(f"fig1/{name}/max_over_mean", 0, f"{mm:.2f}"))
        rows.append(common.row(f"fig1/{name}/excess_kurtosis", 0,
                               f"{kurt:.2f}"))
    b_mm, _ = _stats(x)
    s_mm, _ = _stats(x / s)
    h_mm, _ = _stats(block_hadamard_matmul(x, 128))
    rows.append(common.row(
        "fig1/claim_preprocessing_flattens", 0,
        "PASS" if (s_mm < b_mm and h_mm < b_mm) else "FAIL"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()

"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> measure.

Three selected cells (from the baseline roofline table):
  * glm4-9b x prefill_32k      — most representative of the paper's
    technique (7B-class quantized prefill, Table 3's setting); memory-bound.
  * mixtral-8x22b x decode_32k — most collective-bound (FSDP weight gathers
    dwarf decode compute by ~1000x).
  * llama-3.2-vision-90b x train_4k — worst roofline fraction of the big
    cells; collective-bound (microbatched FSDP re-gathers).

Each iteration is a dryrun variant (flags/env) compiled fresh; results are
appended to results/perf_log.json which experiments_md.py renders into
EXPERIMENTS.md §Perf. Stop rule: 3 consecutive <5% improvements on the
dominant term.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RESULTS = os.path.join(ROOT, "results", "dryrun")
PERF_LOG = os.path.join(ROOT, "results", "perf_log.json")


def run_variant(arch, shape, tag, *, quant="int8", strategy="fsdp_tp",
                kv_bits=16, n_micro=0, env=None):
    """Compile one variant; returns the result dict."""
    mesh = "16x16"
    fname = (f"{arch}__{shape}__{mesh}__{quant}__{strategy}__kv{kv_bits}"
             + (f"__{tag}" if tag else "") + ".json")
    path = os.path.join(RESULTS, fname)
    if not os.path.exists(path):
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--quant", quant, "--strategy", strategy,
               "--kv-bits", str(kv_bits), "--n-micro", str(n_micro)]
        if tag:
            cmd += ["--tag", tag]
        e = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
        e.update(env or {})
        r = subprocess.run(cmd, capture_output=True, text=True, env=e)
        if r.returncode != 0:
            raise RuntimeError(f"variant {tag} failed:\n{r.stdout[-1500:]}")
    with open(path) as f:
        return json.load(f)


def _terms(res):
    t = res["roofline"]
    return {"compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "bound_s": t["step_s_lower_bound"],
            "gib": res["memory"]["peak_bytes_per_device"] / 2**30}


def climb(cell_cfg):
    """Run baseline + iterations; returns the perf-log entry."""
    arch, shape = cell_cfg["arch"], cell_cfg["shape"]
    base = run_variant(arch, shape, "", **cell_cfg.get("base_kw", {}))
    bt = _terms(base)
    print(f"[perf] {arch} x {shape} baseline: {bt}")
    entry = {"cell": f"{arch} x {shape} (16x16)", "why": cell_cfg["why"],
             "baseline": {"config": cell_cfg.get("base_desc",
                                                 "int8, fsdp_tp"), **bt},
             "iterations": []}
    best = dict(bt)
    best_kw = dict(cell_cfg.get("base_kw", {}))
    misses = 0
    for it in cell_cfg["iterations"]:
        if misses >= 3:
            print(f"[perf] stop rule: 3 consecutive <5% improvements")
            break
        kw = {**best_kw, **it.get("kw", {})} if it.get("cumulative", True) \
            else {**cell_cfg.get("base_kw", {}), **it.get("kw", {})}
        env = {**(best_kw.pop("env", {}) if False else {}),
               **it.get("env", {})}
        if it.get("cumulative", True) and "env" in best_kw:
            env = {**best_kw["env"], **env}
        kw["env"] = env
        res = run_variant(arch, shape, it["tag"], **kw)
        at = _terms(res)
        dom = bt["dominant"]
        before = best[dom]
        after = at[dom]
        improved = after < before * 0.95 and at["gib"] <= 16.5
        verdict = ("confirmed" if improved and it["expect_improve"] else
                   "refuted" if not improved and it["expect_improve"] else
                   "expected-neutral" if not improved else "surprise-win")
        entry["iterations"].append({
            "hypothesis": it["hypothesis"], "change": it["change"],
            "before_s": before, "after_s": after, "verdict": verdict,
            "terms": at})
        print(f"[perf] {it['tag']}: {dom} {before:.4f} -> {after:.4f} "
              f"({verdict}); gib={at['gib']:.1f}")
        if improved:
            best, best_kw, misses = at, kw, 0
        else:
            misses += 1
    entry["final"] = {"bound_s": best["bound_s"],
                      "note": cell_cfg.get("final_note", "")}
    return entry


CELLS = [
    {
        "arch": "glm4_9b", "shape": "prefill_32k",
        "why": ("most representative of the paper's technique: 7B-class "
                "INT8 prefill (Table 3's setting); baseline memory-bound "
                "on chunked-attention K/V re-reads"),
        "base_desc": "int8, fsdp_tp, q-chunk 128 (score budget 2^31)",
        "iterations": [
            {"tag": "chunk512", "expect_improve": True,
             "hypothesis": ("memory term is dominated by per-q-chunk K/V "
                            "re-reads (nc=256 chunks re-stream 32k keys "
                            "x28 layers); 4x bigger chunks cut re-reads "
                            "~4x on the attention share"),
             "change": "attention score budget 2^31 -> 2^33 (q-chunk 512)",
             "env": {"REPRO_SCORE_BUDGET_LOG2": "33"}},
            {"tag": "grouped", "expect_improve": True,
             "hypothesis": ("GQA repeat materializes 32-head K from the "
                            "2-head cache inside every chunk (16x K-read "
                            "inflation); the grouped einsum keeps K at 2 "
                            "heads (glm4's per-group head dim 16 still "
                            "shards)"),
             "change": "grouped-GQA score einsum (REPRO_GQA_GROUPED=1)",
             "env": {"REPRO_GQA_GROUPED": "1"}},
            {"tag": "w4a8", "expect_improve": False,
             "hypothesis": ("weights are 9.4 GB int8 vs TBs of attention "
                            "traffic at 32k: halving weight reads moves "
                            "the memory term <5%"),
             "change": "W4A8 weights (per-group int4)",
             "kw": {"quant": "w4a8"}},
            {"tag": "chunk2k", "expect_improve": True,
             "hypothesis": "another 4x chunk size, 4x fewer K re-reads",
             "change": "score budget 2^35 (q-chunk 2048)",
             "env": {"REPRO_SCORE_BUDGET_LOG2": "35"}},
            {"tag": "bf16scores", "expect_improve": True,
             "hypothesis": ("top_bytes shows 4.5 TB/dev of f32 score-chain "
                            "HBM round-trips (the thing a flash kernel "
                            "keeps in VMEM); bf16 score storage halves it"),
             "change": "REPRO_SCORES_BF16=1 (+q-chunk 2048)",
             "env": {"REPRO_SCORES_BF16": "1"}},
        ],
        "final_note": ("9.6s -> top_bytes attribution: 4.5 TB/dev of f32 "
                       "score-chain HBM round-trips — exactly what a fused "
                       "flash kernel keeps in VMEM. Analytic flash bound: "
                       "K/V streams only = nc x T x kv x hd x 40L = 0.9s "
                       "-> compute-bound at 0.59s (63% of int8 roofline). "
                       "bf16-score storage is unmeasurable on CPU-lowered "
                       "HLO (softmax upcasts regardless)"),
    },
    {
        "arch": "mixtral_8x22b", "shape": "decode_32k",
        "why": ("most collective-bound cell: per-layer FSDP gathers of "
                "int8 expert weights dwarf the 1-token decode compute "
                "by ~1500x"),
        "base_desc": "int8, fsdp_tp (2-D weight sharding, gather per layer)",
        "iterations": [
            {"tag": "ws", "expect_improve": True,
             "hypothesis": ("decode moves whole expert weights over ICI "
                            "every layer; weight-stationary sharding over "
                            "the combined 256-way axis keeps weights "
                            "resident (141 GB int8 / 256 = 0.55 GB/dev) "
                            "and all-reduces tiny (B,1,d) activations "
                            "instead"),
             "change": "--strategy ws (weight-stationary serving layout)",
             "kw": {"strategy": "ws"}},
            {"tag": "kv8", "expect_improve": True,
             "hypothesis": ("with gathers gone, the rolling SWA cache "
                            "(4096-slot) read dominates memory; int8 KV "
                            "halves it"),
             "change": "int8 KV cache (W8A8KV8)",
             "kw": {"kv_bits": 8}},
            {"tag": "w4a8", "expect_improve": True,
             "hypothesis": ("decode is weight-read bound per token; int4 "
                            "weights halve resident-weight traffic"),
             "change": "W4A8 weights",
             "kw": {"quant": "w4a8"}},
            {"tag": "ws2", "expect_improve": True,
             "hypothesis": ("the surviving 0.085s is an s8 wo all-gather "
                            "x56 (ws K-shards OUT matrices -> XLA gathers "
                            "them) + s32 expert-accum reduces; N-sharding "
                            "OUT matrices (ws2) keeps every weight "
                            "stationary and reduces only (B,1,d) "
                            "activations"),
             "change": "--strategy ws2 (N-sharded OUT matrices)",
             "kw": {"strategy": "ws2"}},
        ],
        "final_note": ("3.4x: weight-stationary + int8 KV is the "
                       "deployment layout; ws2 (N-sharded OUT) and w4a8 "
                       "both refuted — the residual 0.085s is the wo "
                       "gather + s32 expert-accum reduces, whose fix is "
                       "reduce-in-bf16 + gather/compute overlap"),
    },
    {
        "arch": "llama32_vision_90b", "shape": "train_4k",
        "why": ("worst roofline fraction among the large cells; "
                "collective-bound: n_micro=8 gradient accumulation "
                "re-gathers FSDP weights every microbatch"),
        "base_desc": "bf16, fsdp_tp, n_micro=8 (auto)",
        "iterations": [
            {"tag": "bf16params", "expect_improve": True,
             "cumulative": False,
             "hypothesis": ("the dominant collectives are f32 grad/act "
                            "all-reduces; bf16 parameter storage (f32 "
                            "AdamW moments kept) halves every dw reduce "
                            "and weight gather byte"),
             "change": "REPRO_PARAM_DTYPE=bf16 (mixed-precision training)",
             "env": {"REPRO_PARAM_DTYPE": "bf16"}},
            {"tag": "bf16sc", "expect_improve": True, "cumulative": False,
             "hypothesis": ("top collectives are activation/grad-shaped f32 "
                            "dp all-reduces x160 (600+ GiB) — per-token "
                            "traffic; f32 score/act precision is the "
                            "multiplier to attack, not n_micro"),
             "change": "chunk 2^35 + bf16 scores",
             "env": {"REPRO_SCORE_BUDGET_LOG2": "35",
                     "REPRO_SCORES_BF16": "1"}},
            {"tag": "seqshard", "expect_improve": True, "cumulative": False,
             "hypothesis": ("sequence-parallel boundary sharding "
                            "(Megatron-SP: S over model at layer "
                            "boundaries) re-routes the f32 residual "
                            "all-reduces to smaller reshards"),
             "change": "REPRO_ACT_SPEC=seq (+chunk 2^35, bf16 scores)",
             "env": {"REPRO_ACT_SPEC": "seq",
                     "REPRO_SCORE_BUDGET_LOG2": "35",
                     "REPRO_SCORES_BF16": "1"}},
            {"tag": "nmicro4", "expect_improve": True,
             "hypothesis": ("weight all-gathers scale with n_micro; "
                            "halving it halves the collective term if "
                            "activations still fit (15.8 -> ~20 GiB risk)"),
             "change": "--n-micro 4",
             "kw": {"n_micro": 4}},
            {"tag": "nmicro4-chunk512", "expect_improve": True,
             "hypothesis": ("bigger attention chunks cut both score-buffer "
                            "memory (fits n_micro=4) and K/V re-read "
                            "traffic"),
             "change": "n_micro 4 + score budget 2^33",
             "kw": {"n_micro": 4},
             "env": {"REPRO_SCORE_BUDGET_LOG2": "33"}},
            {"tag": "nmicro2-chunk512", "expect_improve": True,
             "hypothesis": "quarter the gathers if memory allows",
             "change": "n_micro 2 + score budget 2^33",
             "kw": {"n_micro": 2},
             "env": {"REPRO_SCORE_BUDGET_LOG2": "33"}},
        ],
        "final_note": ("negative result with full attribution: the 107.9s "
                       "term is the standard Megatron-TP row-parallel "
                       "activation all-reduce (f32[2,4096,8192] x160 = "
                       "4/layer x 100L x 8 micro), NOT FSDP weight "
                       "gathers — n_micro, bf16 params, and boundary "
                       "re-sharding are all refuted as predicted once "
                       "attribution was in hand. ~2x of it is CPU-backend "
                       "f32 staging of bf16 partial sums (TPU reduces "
                       "bf16: ~54s adjusted). The framework-level fixes "
                       "are Megatron sequence-parallelism inside the "
                       "layer (not boundary constraints — measured 3.2x "
                       "worse) and comm/compute overlap; cross-pod, the "
                       "int8-compressed gradient all-reduce "
                       "(trainer.int8_allreduce) halves DCN bytes"),
    },
]


def main(print_rows=True):
    log = []
    for cell in CELLS:
        try:
            log.append(climb(cell))
        except Exception as e:
            print(f"[perf] {cell['arch']} x {cell['shape']} failed: {e}")
    os.makedirs(os.path.dirname(PERF_LOG), exist_ok=True)
    with open(PERF_LOG, "w") as f:
        json.dump(log, f, indent=1)
    print(f"[perf] wrote {PERF_LOG}")
    return []


if __name__ == "__main__":
    main()

"""Aggregate the dry-run artifacts (results/dryrun/*.json) into the
EXPERIMENTS.md roofline tables: per (arch x shape x mesh), the three terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, memory fit."""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")
HBM = 16 * 2**30


def load_all():
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def fmt_row(r):
    t = r["roofline"]
    m = r["memory"]["peak_bytes_per_device"] / 2**30
    fit = "ok" if m <= 16 else f"OVER({m:.0f}G)"
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['quant']} | "
            f"{t['compute_s']:.4f} | {t['memory_s']:.4f} | "
            f"{t['collective_s']:.4f} | {t['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | {m:.1f} | {fit} |")


def main(print_rows=True):
    rows = []
    recs = load_all()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    errors = [r for r in recs if r.get("status") == "error"]
    rows.append(f"roofline/cells_ok,0,{len(ok)}")
    rows.append(f"roofline/cells_skipped,0,{len(skipped)}")
    rows.append(f"roofline/cells_error,0,{len(errors)}")
    for r in ok:
        t = r["roofline"]
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r['quant']}"
        rows.append(f"{name},{t['step_s_lower_bound'] * 1e6:.0f},"
                    f"dom={t['dominant'].replace('_s', '')}"
                    f";useful={r['useful_flops_ratio']:.2f}")
    for r in errors:
        rows.append(f"roofline/ERROR/{r['arch']}/{r['shape']}/{r['mesh']},0,"
                    f"{r['error'][:60]}")
    if print_rows:
        for r_ in rows:
            print(r_)
    return rows


def markdown_table(mesh=None):
    recs = [r for r in load_all() if r.get("status") == "ok"]
    if mesh:
        recs = [r for r in recs if r["mesh"] == mesh]
    hdr = ("| arch | shape | mesh | quant | compute_s | memory_s | "
           "collective_s | dominant | useful | GiB/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(fmt_row(r) for r in recs)


if __name__ == "__main__":
    main()
    print(markdown_table())

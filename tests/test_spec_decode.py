"""Draft-free self-speculative decoding: n-gram drafter lookup, verify-step
equivalence with sequential decode, page-exact rollback (kv_pool.truncate),
and engine-level guarantees — bf16 greedy bit-exactness vs vanilla decode
(including under preemption), int8 smoke + counter consistency, budget stops
mid-window, and the one-extra-program compile-count bound."""
import types

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import QUANT_KV_BITS, make_engine

from repro.models import transformer
from repro.serving import ContinuousBatchingEngine
from repro.serving.draft import NgramDrafter
from repro.serving import kv_pool


# ---------------------------------------------------------------------------
# NgramDrafter
# ---------------------------------------------------------------------------

def test_drafter_copies_continuation_of_trailing_ngram():
    d = NgramDrafter(5, ngram_max=3, ngram_min=2)
    # trailing [1,2,3] recurs at the start; lag 5 -> copy x[t-5] forward
    assert d.propose([1, 2, 3, 4, 5, 1, 2, 3]) == [4, 5, 1, 2, 3]


def test_drafter_prefers_most_recent_occurrence():
    d = NgramDrafter(1, ngram_max=3, ngram_min=2)
    # [1,2,3] occurs twice with different continuations (7 then 8): the
    # match closest to the end wins
    assert d.propose([9, 1, 2, 3, 7, 1, 2, 3, 8, 1, 2, 3]) == [8]


def test_drafter_prefers_longest_ngram():
    d = NgramDrafter(1, ngram_max=3, ngram_min=2)
    # 2-gram [2,3] recurs most recently before 9, but the 3-gram [1,2,3]
    # also recurs (before 7) and is tried first
    assert d.propose([1, 2, 3, 7, 5, 2, 3, 9, 1, 2, 3]) == [7]


def test_drafter_lag_recurrence_rolls_into_drafts():
    d = NgramDrafter(6, ngram_max=3, ngram_min=2)
    # period-2 loop: the copy source runs off the context's end and reads
    # the drafts themselves, still yielding all k tokens
    assert d.propose([4, 7, 4, 7, 4, 7]) == [4, 7, 4, 7, 4, 7]


def test_drafter_empty_on_fresh_context():
    d = NgramDrafter(4, ngram_max=3, ngram_min=2)
    assert d.propose(list(range(20))) == []
    assert d.propose([3]) == []                   # too short to have a bigram


def test_drafter_ngram_min_blocks_single_token_matches():
    ctx = [3, 1, 4, 1]                            # only the 1-gram [1] recurs
    assert NgramDrafter(4, ngram_max=3, ngram_min=2).propose(ctx) == []
    assert NgramDrafter(4, ngram_max=3, ngram_min=1).propose(ctx) == \
        [4, 1, 4, 1]


def test_drafter_k_clamps():
    d = NgramDrafter(8, ngram_max=3, ngram_min=2)
    ctx = [1, 2, 3, 4, 5, 1, 2, 3]
    assert d.propose(ctx, k=2) == [4, 5]
    assert d.propose(ctx, k=0) == []


# ---------------------------------------------------------------------------
# kv_pool.truncate: rollback is bit-identical to never having speculated
# ---------------------------------------------------------------------------

def test_truncate_bit_identical_to_direct_write(kv_bits):
    cfg = types.SimpleNamespace(n_kv_heads=2, hd=4)
    page, c = 4, 5                                # k+1 window, unaligned
    pool0 = kv_pool.init_pool(cfg, n_pages=8, page_size=page,
                              kv_bits=kv_bits)
    rng = np.random.default_rng(0)
    rows = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    # pre-existing history so the boundary page holds old tokens
    hist = [jnp.asarray(rng.normal(size=(2, c, 2, 4)), jnp.float32)
            for _ in range(2)]
    start = jnp.asarray([3, 1], jnp.int32)
    pool0 = kv_pool.write_chunk(pool0, hist[0], hist[1], rows,
                                jnp.zeros(2, jnp.int32), start)
    k = jnp.asarray(rng.normal(size=(2, c, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, c, 2, 4)), jnp.float32)
    n_keep = jnp.asarray([2, 4], jnp.int32)

    snap = {leaf: pool0[leaf][rows] for leaf in pool0}
    full = kv_pool.write_chunk(pool0, k, v, rows, start,
                               jnp.full(2, c, jnp.int32))
    rolled = kv_pool.truncate(full, rows, snap, k, v, start, n_keep)
    direct = kv_pool.write_chunk(pool0, k, v, rows, start, n_keep)
    for leaf in pool0:
        np.testing.assert_array_equal(np.asarray(rolled[leaf]),
                                      np.asarray(direct[leaf]))


# ---------------------------------------------------------------------------
# verify_step_paged == sequential decode_step_paged (bf16 pools)
# ---------------------------------------------------------------------------

def test_verify_window_matches_sequential_decode(cfg_params):
    """Scoring a k+1 window in one verify pass reproduces the logits the
    vanilla chain produces token-by-token (bf16: the raw-window splice is
    exactly what decode would have written; int8 deviates by design —
    covered at engine level)."""
    cfg, params = cfg_params
    pools = transformer.init_paged_pools(cfg, n_pages=8, page_size=8,
                                         kv_bits=16)
    pt = jnp.asarray([[1, 2, 3]], jnp.int32)
    toks = list(np.random.default_rng(1).integers(0, cfg.vocab, 8))

    ref, pv = [], pools
    for i, t in enumerate(toks):
        lg, pv = transformer.decode_step_paged(
            params, pv, pt, jnp.asarray([t], jnp.int32),
            jnp.asarray([i], jnp.int32), cfg)
        ref.append(np.asarray(lg[0]))

    pw = pools
    for i, t in enumerate(toks[:4]):              # shared history
        _, pw = transformer.decode_step_paged(
            params, pw, pt, jnp.asarray([t], jnp.int32),
            jnp.asarray([i], jnp.int32), cfg)
    win, _ = transformer.verify_step_paged(
        params, pw, pt, jnp.asarray([toks[4:]], jnp.int32),
        jnp.asarray([4], jnp.int32), jnp.asarray([4], jnp.int32), cfg)
    win = np.asarray(win[0])                      # (4, V)
    for j in range(4):
        assert int(win[j].argmax()) == int(ref[4 + j].argmax())
        np.testing.assert_allclose(win[j], ref[4 + j], rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# engine: bf16 greedy speculation is bit-exact with vanilla decode
# ---------------------------------------------------------------------------

def _loopy_prompts():
    # short prompts whose reduced-model greedy continuations loop quickly,
    # so the drafter actually fires (same family the bench warmup uses)
    return [[7] * 8 + list(range(16)),
            [5] * 12 + [1, 2, 3, 4],
            [9, 9, 9, 9] + list(range(30, 42))]


MK = dict(page_size=8, max_batch=3, max_seq_len=96)


def test_engine_spec_bf16_greedy_bit_exact(cfg_params):
    cfg, params = cfg_params
    prompts = _loopy_prompts()
    want = ContinuousBatchingEngine(params, cfg, kv_bits=16, **MK).run(
        prompts, max_new=32)
    eng = ContinuousBatchingEngine(params, cfg, kv_bits=16, spec_decode=4,
                                   spec_gate=0.5, **MK)
    got = eng.run(prompts, max_new=32)
    assert got.tokens == want.tokens
    assert got.spec_steps > 0                     # speculation actually ran
    assert got.accepted_tokens > 0
    st = eng.spec_stats()
    assert st["accepted_tokens"] <= st["draft_tokens"]
    assert 0.0 <= st["acceptance_rate"] <= 1.0
    assert eng.compile_counts() == {"prefill": 0, "mixed": 1, "decode": 1,
                                    "verify": 1}


def test_engine_spec_bit_exact_under_preemption(cfg_params):
    """A tight pool preempts mid-speculation: rollback + requeue must still
    reproduce the roomy vanilla engine token-for-token."""
    cfg, params = cfg_params
    prompts = _loopy_prompts()
    want = ContinuousBatchingEngine(params, cfg, kv_bits=16, **MK).run(
        prompts, max_new=24)
    tight = ContinuousBatchingEngine(params, cfg, kv_bits=16, spec_decode=4,
                                     spec_gate=0.5, n_pages=16, **MK)
    got = tight.run(prompts, max_new=24)
    assert got.tokens == want.tokens
    assert got.evictions > 0                      # preemption happened


def test_engine_spec_budget_stops_mid_window(cfg_params):
    """max_new smaller than the k+1 window: accepted tokens past the budget
    must be dropped, not emitted."""
    cfg, params = cfg_params
    prompts = _loopy_prompts()
    want = ContinuousBatchingEngine(params, cfg, kv_bits=16, **MK).run(
        prompts, max_new=5)
    eng = ContinuousBatchingEngine(params, cfg, kv_bits=16, spec_decode=4,
                                   spec_gate=0.5, **MK)
    got = eng.run(prompts, max_new=5)
    assert got.tokens == want.tokens
    assert all(len(t) <= 5 for t in got.tokens)


@pytest.mark.parametrize("kv_bits", QUANT_KV_BITS)
def test_engine_spec_quantized_smoke(cfg_params, kv_bits):
    """Quantized pools (int8 and packed int4) re-round pages write-by-write,
    so batched verify is not bit-exact with vanilla by design — the
    machinery must still produce valid tokens, consistent counters, and the
    same compile-count bound."""
    cfg, params = cfg_params
    eng = make_engine(params, cfg, kv_bits=kv_bits, spec_decode=4,
                      spec_gate=0.5, **MK)
    got = eng.run(_loopy_prompts(), max_new=32)
    assert all(len(t) <= 32 for t in got.tokens)
    assert all(0 <= tok < cfg.vocab for t in got.tokens for tok in t)
    assert got.draft_tokens >= got.accepted_tokens >= 0
    if got.spec_steps:
        assert eng.compile_counts()["verify"] == 1
    assert sum(eng.compile_counts().values()) <= 3


def test_spec_requires_chunked_prefill(cfg_params):
    cfg, params = cfg_params
    with pytest.raises(AssertionError, match="chunked"):
        ContinuousBatchingEngine(params, cfg, prefill_mode="legacy",
                                 spec_decode=4, **MK)

"""End-to-end PTQ: calibrate -> quantize_model -> quantized forward/serving
across architectures and all four paper configurations."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core.quant import INT8, W4A8, W4A8_SMOOTH, W4A8_HADAMARD
from repro.core.quant import calibrate, ptq
from repro.models import transformer


def setup_model(arch="pangu_1b", seed=0, b=2, s=16):
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(seed)
    params = transformer.init_params(key, cfg)
    batches = []
    for i in range(2):
        k = jax.random.PRNGKey(100 + i)
        batch = {}
        if cfg.frontend == "embeddings":
            batch["embeds"] = jax.random.normal(k, (b, s, cfg.d_model))
        else:
            batch["tokens"] = jax.random.randint(k, (b, s), 0, cfg.vocab)
        if cfg.frontend == "tokens+image":
            batch["ctx"] = jax.random.normal(k, (b, cfg.n_ctx_tokens,
                                                 cfg.d_model))
        batches.append(batch)
    return cfg, params, batches


@pytest.mark.parametrize("qcfg", [INT8, W4A8, W4A8_SMOOTH, W4A8_HADAMARD],
                         ids=["int8", "w4a8", "w4a8-smooth", "w4a8-hadamard"])
@pytest.mark.parametrize("arch", ["pangu_1b", "mixtral_8x7b", "hymba_1_5b",
                                  "xlstm_350m"])
def test_ptq_forward_close_to_fp(arch, qcfg):
    cfg, params, batches = setup_model(arch)
    stats = calibrate.collect_stats(params, batches, cfg)
    for k, v in stats.items():
        assert v.shape == (cfg.n_groups, v.shape[-1]) and (v >= 0).all(), k
    pq = ptq.quantize_model(params, cfg, qcfg, stats)
    logits_fp, _ = transformer.forward_train(params, batches[0], cfg,
                                             remat=False)
    logits_q, _ = transformer.forward_train(pq, batches[0], cfg, qcfg=qcfg,
                                            impl="xla", remat=False)
    assert bool(jnp.isfinite(logits_q).all())
    p = jax.nn.softmax(logits_fp, -1)
    logq = jax.nn.log_softmax(logits_q, -1)
    logp = jax.nn.log_softmax(logits_fp, -1)
    kl = float(jnp.mean(jnp.sum(p * (logp - logq), -1)))
    # random-init tiny model: int8 should be near-lossless, w4a8 degraded
    bound = 0.05 if qcfg.weight_bits == 8 else 1.0
    assert kl < bound, f"{arch} {qcfg.name}: KL {kl}"


def test_ptq_decode_path_runs_quantized():
    cfg, params, batches = setup_model("pangu_1b")
    stats = calibrate.collect_stats(params, batches, cfg)
    pq = ptq.quantize_model(params, cfg, INT8, stats)
    b, s = 2, 8
    toks = batches[0]["tokens"][:, :s]
    logits_pre, caches = transformer.prefill(pq, {"tokens": toks}, cfg,
                                             max_len=s + 4, qcfg=INT8,
                                             impl="xla")
    pos = jnp.full((b,), s, jnp.int32)
    nxt = jnp.argmax(logits_pre, -1).astype(jnp.int32)
    logits_dec, caches = transformer.decode_step(pq, caches, nxt, pos, cfg,
                                                 qcfg=INT8, impl="xla")
    assert logits_dec.shape == (b, cfg.vocab)
    assert bool(jnp.isfinite(logits_dec).all())


def test_ptq_eval_shape_aot():
    """PTQ must be eval_shape-able (dry-run uses this to get quantized
    param specs without materializing 90B weights)."""
    cfg, params, _ = setup_model("pangu_1b")
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          params)
    qshapes = ptq.quantized_param_shapes(shapes, cfg, W4A8_SMOOTH)
    leaves = jax.tree.leaves(qshapes)
    assert any(l.dtype == jnp.int8 for l in leaves)
    # packed int4: w_in of mlp has K=d_model -> data K/2
    blk = qshapes["blocks"]["0"]["mlp"]["w_in"]["w_q"]
    assert blk.data.shape[-2] == cfg.d_model // 2


def test_paper_claims_int8_vs_w4a8_and_flatness():
    """Deterministic end-to-end claims from the paper:

    1. Tables 1-2: INT8 is near-lossless while baseline W4A8 degrades
       significantly (>=10x larger logit error here).
    2. Figure 1: SmoothQuant and Hadamard preprocessing flatten the
       channel-wise |x| distribution feeding the quantizer.

    (Scheme *ordering* under W4A8 on trained weights is measured by
    benchmarks/table2_w4a8.py on a trained model — at tiny random-init
    scale 4-bit weight noise dominates and the ordering is seed noise.)
    """
    cfg, params, batches = setup_model("pangu_1b", seed=3)
    emb = np.array(params["embed"]["w"], copy=True)
    rng = np.random.default_rng(7)
    idx = rng.choice(cfg.d_model, size=cfg.d_model // 8, replace=False)
    emb[:, idx] *= rng.uniform(30, 80, size=len(idx))  # LLM-like outliers
    params["embed"]["w"] = jnp.asarray(emb)
    stats = calibrate.collect_stats(params, batches, cfg)
    logits_fp, _ = transformer.forward_train(params, batches[0], cfg,
                                             remat=False)

    errs = {}
    for name, qcfg in [("int8", INT8), ("w4a8", W4A8)]:
        pq = ptq.quantize_model(params, cfg, qcfg, stats)
        lq, _ = transformer.forward_train(pq, batches[0], cfg, qcfg=qcfg,
                                          impl="xla", remat=False)
        errs[name] = float(jnp.mean((lq - logits_fp) ** 2))
    assert errs["int8"] * 10 < errs["w4a8"], errs

    # Figure 1: channel absmax flatness at the first quant site.
    from repro.core.quant import smooth as sm
    from repro.core.quant.hadamard import block_hadamard_matmul
    from repro.models.layers import rms_norm
    x = rms_norm(params["embed"]["w"][batches[0]["tokens"]].astype(
        jnp.float32).reshape(-1, cfg.d_model), jnp.ones(cfg.d_model))
    w = params["blocks"]["0"]["attn"]["wqkv"]["w"][0]
    a_am, w_am = jnp.max(jnp.abs(x), 0), jnp.max(jnp.abs(w), 1)
    # Fig. 1's halved-flatness claim holds for the *tuned* migration
    # strength (SmoothQuant's alpha is model-dependent); alpha=0.5 on this
    # synthetic outlier model under-migrates (act flatness stays ~5x while
    # the weight side sits near 1.7 — free headroom).
    s = sm.smooth_scales(a_am, w_am, alpha=sm.search_alpha(a_am, w_am, w))

    def flatness(t):  # max/mean of channel absmax (Fig. 1 y-axis shape)
        am = jnp.max(jnp.abs(t), axis=0)
        return float(jnp.max(am) / jnp.mean(am))

    f_plain = flatness(x)
    f_smooth = flatness(x / s)
    f_had = flatness(block_hadamard_matmul(x, 128))
    assert f_smooth < f_plain / 2, (f_plain, f_smooth)
    assert f_had < f_plain / 2, (f_plain, f_had)
    # The searched alpha must still produce scales the weight side absorbs:
    # per-output-channel quantization cares about the spread of column
    # absmax after S W (migration balance, Eq. 3).
    f_w = flatness(w * s[:, None])
    assert f_w < f_plain / 2, (f_plain, f_w)

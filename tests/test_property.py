"""Hypothesis property tests on the quantization core's invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements.txt)")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core.quant import qtypes, smooth
from repro.core.quant.hadamard import (block_fwht, block_hadamard_matmul,
                                       rotate_weight)
from repro.serving import cot

_settings = settings(max_examples=25, deadline=None)


def floats(shape):
    return hnp.arrays(np.float32, shape,
                      elements=st.floats(-100, 100, width=32,
                                         allow_nan=False))


# -- quantization error bound -------------------------------------------------

@_settings
@given(floats((16, 32)), st.sampled_from([4, 8]))
def test_fake_quant_error_bounded_by_half_scale(x, bits):
    """|x - Q(x)| <= s/2 + eps for non-clipped values (round-to-nearest)."""
    xq = np.asarray(qtypes.fake_quant(jnp.asarray(x), bits, axis=None))
    absmax = np.abs(x).max()
    s = max(2 * absmax / (2 ** bits - 1), 1e-8)
    # the extreme elements may clip by one step (paper scale uses 2^n - 1)
    assert (np.abs(x - xq) <= s + 1e-5).all()
    inner = np.abs(x) < absmax * (1 - 2 / (2 ** bits))
    if inner.any():
        assert (np.abs(x - xq)[inner] <= s / 2 + 1e-5).all()


@_settings
@given(floats((8, 64)))
def test_quantize_act_idempotent_scaleinvariant(x):
    """Per-token quantization is invariant to positive per-token scaling
    (up to 1 level at rounding boundaries — fp division of the scaled pair
    differs by 1 ulp; and the 1e-8 eps floor breaks it for ~zero rows)."""
    rows_live = np.abs(x).max(axis=1) > 1e-3
    q1, s1 = qtypes.quantize_act(jnp.asarray(x))
    q2, s2 = qtypes.quantize_act(jnp.asarray(x * 4.0))
    diff = np.abs(np.asarray(q1, np.int32) - np.asarray(q2, np.int32))
    assert (diff[rows_live] <= 1).all()
    np.testing.assert_allclose(np.asarray(s2)[rows_live],
                               (np.asarray(s1) * 4.0)[rows_live], rtol=1e-5)


# -- int4 packing roundtrips ----------------------------------------------------

@_settings
@given(hnp.arrays(np.int8, (32, 16),
                  elements=st.integers(-8, 7)),
       st.sampled_from([4, 8, 16, 32]))
def test_pack_halves_roundtrip(vals, group):
    packed = qtypes.pack_int4_halves(jnp.asarray(vals), group)
    assert packed.shape == (16, 16)
    back = qtypes.unpack_int4_halves(packed, group)
    np.testing.assert_array_equal(np.asarray(back), vals)


@_settings
@given(hnp.arrays(np.int8, (24, 8), elements=st.integers(-8, 7)))
def test_pack_interleave_roundtrip(vals):
    packed = qtypes.pack_int4(jnp.asarray(vals), 0)
    back = qtypes.unpack_int4(packed, 0, 24)
    np.testing.assert_array_equal(np.asarray(back), vals)


@_settings
@given(st.integers(1, 6), st.sampled_from([2, 4, 6, 10, 16, 32]),
       st.data())
def test_pack_lastdim_roundtrip(rows, d, data):
    """The KV-page layout (grouped halves along the last axis) round-trips
    every nibble value, including the -8 storage edge the narrow symmetric
    quantizer never emits, for odd and even half-group sizes."""
    vals = data.draw(hnp.arrays(np.int8, (rows, d),
                                elements=st.integers(-8, 7)))
    packed = qtypes.pack_int4_halves_lastdim(jnp.asarray(vals))
    assert packed.shape == (rows, d // 2) and packed.dtype == jnp.uint8
    back = qtypes.unpack_int4_halves_lastdim(packed)
    assert back.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(back), vals)


@_settings
@given(st.sampled_from([(4, 2, 2), (3, 1, 8), (2, 5, 6)]), st.data())
def test_pack_lastdim_roundtrip_nd(shape, data):
    """Round-trip holds for >2-d arrays — pages are (page, nkv, hd)."""
    vals = data.draw(hnp.arrays(np.int8, shape,
                                elements=st.integers(-8, 7)))
    back = qtypes.unpack_int4_halves_lastdim(
        qtypes.pack_int4_halves_lastdim(jnp.asarray(vals)))
    np.testing.assert_array_equal(np.asarray(back), vals)


@_settings
@given(floats((8, 16)))
def test_int4_quant_pack_roundtrip_error_bounded(x):
    """quantize -> pack -> unpack -> dequantize deviates from the input by
    at most s/2 + eps per element: the narrow symmetric clip at +-qmax(4)
    lands the absmax exactly on a code, so no element clips by more than
    half a step."""
    am = np.abs(x).max(axis=-1, keepdims=True)
    s = np.maximum(np.asarray(qtypes.paper_scale(jnp.asarray(am), 4)), 1e-8)
    q = np.clip(np.rint(x / s), qtypes.qmin(4), qtypes.qmax(4)).astype(
        np.int8)
    back = qtypes.unpack_int4_halves_lastdim(
        qtypes.pack_int4_halves_lastdim(jnp.asarray(q)))
    deq = np.asarray(back, np.float32) * s
    # relative eps: the absmax element sits exactly at s/2, so f32
    # rounding of x/s can spill a few ulp past the bound
    assert (np.abs(deq - x) <= s / 2 * (1 + 1e-4) + 1e-6).all()


# -- smoothing invariants ---------------------------------------------------------

@_settings
@given(floats((8, 32)), floats((32, 16)), st.floats(0.1, 0.9))
def test_smooth_identity_in_fp(x, w, alpha):
    a_max = np.abs(x).max(0) + 1e-3
    w_max = np.abs(w).max(1) + 1e-3
    s = smooth.smooth_scales(jnp.asarray(a_max), jnp.asarray(w_max), alpha)
    y0 = x @ w
    y1 = (x / np.asarray(s)) @ np.asarray(
        smooth.apply_to_weight(jnp.asarray(w), s))
    np.testing.assert_allclose(y1, y0, rtol=2e-3, atol=2e-3)
    assert (np.asarray(s) > 0).all()


# -- hadamard invariants -----------------------------------------------------------

@_settings
@given(floats((4, 256)), st.sampled_from([32, 64, 128]))
def test_fwht_orthogonal_and_norm_preserving(x, block):
    y = np.asarray(block_fwht(jnp.asarray(x), block))
    np.testing.assert_allclose(np.linalg.norm(y, axis=1),
                               np.linalg.norm(x, axis=1), rtol=1e-4)
    back = np.asarray(block_fwht(jnp.asarray(y), block))
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-3)


@_settings
@given(floats((8, 128)), floats((128, 32)))
def test_rotation_preserves_matmul(x, w):
    xr = block_hadamard_matmul(jnp.asarray(x), 128)
    wr = rotate_weight(jnp.asarray(w), 128)
    np.testing.assert_allclose(np.asarray(xr @ wr), x @ w,
                               rtol=1e-2, atol=1e-2)


# -- refcounted page allocator ------------------------------------------------------

@_settings
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 6)),
                min_size=1, max_size=60),
       st.integers(3, 12))
def test_page_allocator_refcount_invariants(ops, n_pages):
    """Random alloc/share/free/cache interleavings against a mirror model:
    no double-free, no leak, and every page is always in exactly one of
    {free, live, parked} — n_free + n_live + n_parked == n_pages - 1."""
    from repro.serving.kv_pool import PageAllocator
    a = PageAllocator(n_pages)
    cached: set = set()                       # mini prefix cache: park these
    parked: list = []                         # mirror of the LRU
    a.reclaim_hook = lambda p: p in cached and (parked.append(p) or True)
    live: dict = {}                           # page -> expected refcount
    for op, arg in ops:
        if op == 0:                           # alloc 1..arg pages
            got = a.alloc(arg % 3 + 1)
            if got is not None:
                for p in got:
                    assert p not in live and p not in parked
                    live[p] = 1
        elif op == 1 and live:                # share an existing mapping
            p = sorted(live)[arg % len(live)]
            a.incref(p)
            live[p] += 1
        elif op == 2 and live:                # release one holder
            p = sorted(live)[arg % len(live)]
            a.free([p])
            live[p] -= 1
            if live[p] == 0:
                del live[p]
        elif op == 3 and live:                # promote into the cache
            cached.add(sorted(live)[arg % len(live)])
        elif op == 4 and parked:              # cache hit on a cold page
            p = parked.pop(arg % len(parked))
            a.adopt(p)
            live[p] = 1
        elif op == 5 and parked:              # cache eviction
            p = parked.pop(arg % len(parked))
            a.reclaim(p)
            cached.discard(p)
        assert a.n_live == len(live)
        assert a.n_parked == len(parked)
        assert all(a.refcount(p) == n for p, n in live.items())
        assert a.n_free + a.n_live + a.n_parked == n_pages - 1
    # drain everything: the pool must come back whole (no leak)
    for p, n in list(live.items()):
        a.free([p] * n)
    for p in list(parked):
        a.reclaim(p)
    assert a.n_free == n_pages - 1 and a.n_live == 0 and a.n_parked == 0


# -- scheduler under speculative decoding ----------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                          st.integers(0, 7)),
                min_size=1, max_size=80),
       st.integers(6, 14), st.integers(2, 3))
def test_scheduler_spec_interleaving_allocator_invariants(ops, n_pages,
                                                         n_slots):
    """Random interleavings of chunked prefill, speculative window growth,
    accept/reject truncation, completion, and the preemption + prefix-cache
    traffic they trigger on a deliberately tiny pool: after every op each
    page is in exactly one of {free, live, parked} —
    n_free + n_live + n_parked == n_pages - 1 — and draining the scheduler
    returns the pool whole."""
    from repro.serving.kv_pool import SCRATCH_PAGE
    from repro.serving.scheduler import PagedScheduler, Request

    page = 4
    chunk = 2 * page
    spec_k = 6
    sched = PagedScheduler(n_slots=n_slots, n_pages=n_pages, page_size=page,
                           max_pages_per_seq=n_pages - 1, prefix_cache=True)
    rid = 0

    def check():
        a = sched.alloc
        assert a.n_free + a.n_live + a.n_parked == n_pages - 1
        # any page a slot maps must be live (never free/parked under a slot)
        for s in sched.active:
            for p in sched.seq_pages[s]:
                assert a.refcount(p) >= 1 and p != SCRATCH_PAGE

    for op, x, y in ops:
        if op == 0:                               # submit + admit
            # prompts drawn from 4 templates so admissions hit the cache
            prompt = [x % 4] * (page * (x % 3 + 1) + y % page + 1)
            sched.submit(Request(rid=rid, prompt=prompt, mode="slow_think",
                                 budget=8))
            rid += 1
            sched.admit(max_prefill_pages=2)
        elif op == 1 and sched.active:            # one prefill chunk
            slots = sched.prefilling_slots()
            if slots:
                s = slots[x % len(slots)]
                goal = min(len(sched.active[s].prompt),
                           int(sched.prefill_progress[s]) + chunk)
                try:
                    sched.grow_to(s, goal)
                except RuntimeError:
                    check()
                    continue
                if s in sched.active:
                    sched.prefill_progress[s] = goal
                    sched.lengths[s] = goal
        elif op == 2 and sched.active:            # speculative step
            slots = sched.decoding_slots()
            if slots:
                s = slots[x % len(slots)]
                drafted = y % (spec_k + 1)
                start = int(sched.lengths[s])
                try:
                    sched.grow_to(s, start + 1 + drafted)
                except RuntimeError:
                    check()
                    continue
                if s in sched.active:             # may have self-preempted
                    accepted = min(x % (spec_k + 1), drafted)
                    sched.lengths[s] = start + 1 + accepted
                    sched.truncate_to(s, start + 1 + accepted)
        elif op == 3 and sched.active:            # finish a request
            slots = sched.decoding_slots()
            if slots:
                sched.complete(slots[x % len(slots)])
        check()

    # drain: finish any outstanding prefill (the engine never completes a
    # mid-prefill slot), then complete — growth may preempt other slots,
    # which simply requeue with their pages released
    while sched.active:
        s = min(sched.active)
        full = len(sched.active[s].prompt)
        if sched.prefill_progress[s] < full:
            sched.grow_to(s, full)
            if s not in sched.active:
                continue
            sched.prefill_progress[s] = full
            sched.lengths[s] = full
        sched.complete(s)
        check()
    a = sched.alloc
    assert a.n_live == 0
    assert a.n_free + a.n_parked == n_pages - 1


# -- repetition detector -------------------------------------------------------------

@_settings
@given(st.lists(st.integers(0, 50), min_size=1, max_size=20),
       st.integers(1, 6), st.integers(3, 6))
def test_repetition_detector_finds_planted(prefix, phrase_len, repeats):
    phrase = list(range(100, 100 + phrase_len))
    toks = prefix + phrase * max(repeats, (12 // phrase_len) + 1)
    assert cot.detect_repetition(toks)


@_settings
@given(st.integers(10, 60))
def test_repetition_detector_clean_on_distinct(n):
    assert not cot.detect_repetition(list(range(n)))

"""Roofline machinery tests: the loop-aware HLO walker must (a) match
XLA cost_analysis on loop-free modules, (b) multiply while bodies by trip
count, (c) count collectives inside loops with multipliers."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.roofline import analysis, hlo_cost, hw


def _compile(f, *specs, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*specs).compile()


def test_walker_matches_cost_analysis_loop_free():
    def f(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2

    s = jax.ShapeDtypeStruct
    comp = _compile(f, s((128, 256), jnp.float32), s((256, 512), jnp.float32),
                    s((512, 64), jnp.float32))
    got = hlo_cost.analyze(comp.as_text())
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    expected_flops = 2 * 128 * 256 * 512 + 2 * 128 * 512 * 64
    assert got["flops"] == expected_flops
    # XLA adds elementwise flops; GEMMs dominate
    assert abs(ca["flops"] - got["flops"]) / got["flops"] < 0.02


def test_walker_multiplies_while_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    s = jax.ShapeDtypeStruct
    comp = _compile(f, s((64, 64), jnp.float32), s((64, 64), jnp.float32))
    got = hlo_cost.analyze(comp.as_text())
    assert got["flops"] == 7 * 2 * 64 * 64 * 64
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < got["flops"]  # XLA undercounts the loop


def test_walker_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, ()
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, ()
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    s = jax.ShapeDtypeStruct
    comp = _compile(f, s((32, 32), jnp.float32), s((32, 32), jnp.float32))
    got = hlo_cost.analyze(comp.as_text())
    assert got["flops"] == 15 * 2 * 32 ** 3


def test_collectives_counted_with_loop_multiplier():
    import os
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run under forced host device count)")


def test_roofline_terms_dominance():
    t = analysis.roofline_terms(hlo_flops_per_dev=1e12,
                                hlo_bytes_per_dev=1e9,
                                link_bytes_per_dev=1e6, n_chips=256)
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 1e12 / hw.PEAK_BF16) < 1e-9
    t2 = analysis.roofline_terms(hlo_flops_per_dev=1e9,
                                 hlo_bytes_per_dev=1e12,
                                 link_bytes_per_dev=1e6, n_chips=256)
    assert t2["dominant"] == "memory_s"


def test_int8_split_peak():
    t = analysis.roofline_terms(hlo_flops_per_dev=2e12,
                                hlo_bytes_per_dev=1.0,
                                link_bytes_per_dev=0.0, n_chips=1,
                                int8_linear_flops_global=2e12)
    # all flops at int8 peak
    assert abs(t["compute_s"] - 2e12 / hw.PEAK_INT8) < 1e-9


def test_collective_ring_adjustments():
    c = hlo_cost.CollectiveUse("all-gather", 100, 4, 2)
    assert c.link_bytes == 100 * 3 * 2
    c = hlo_cost.CollectiveUse("all-reduce", 100, 4, 1)
    assert c.link_bytes == int(2 * 100 * 3 / 4)
    c = hlo_cost.CollectiveUse("collective-permute", 100, 4, 3)
    assert c.link_bytes == 300

"""Flash-attention kernel vs the model's SDPA reference, swept over
(shape, GQA ratio, causality, window, dtype) in interpret mode."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import flash_attention
from repro.models import attention as attn


def ref_sdpa(q, k, v, causal=True, window=0):
    s, t = q.shape[1], k.shape[1]
    if causal:
        mask = attn.causal_mask(s, window=window, t=t)
    else:
        mask = jnp.ones((1, 1, s, t), bool)
    return attn._sdpa(q.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), mask, None).reshape(
        q.shape[0], s, q.shape[2], q.shape[3])


def make(b, s, t, h, g, d, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, t, g, d), dtype)
    v = jax.random.normal(ks[2], (b, t, g, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,g,d,bq,bk", [
    (1, 128, 4, 4, 64, 64, 64),      # MHA
    (2, 128, 4, 2, 64, 32, 64),      # GQA 2x
    (1, 256, 8, 2, 32, 64, 128),     # GQA 4x, rectangular blocks
    (1, 64, 2, 1, 128, 64, 32),      # MQA
])
def test_flash_causal_matches_ref(b, s, h, g, d, bq, bk):
    q, k, v = make(b, s, s, h, g, d)
    got = flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                          interpret=True)
    want = ref_sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_sliding_window():
    q, k, v = make(1, 256, 256, 4, 2, 32, seed=3)
    got = flash_attention(q, k, v, causal=True, window=64, bq=64, bk=64,
                          interpret=True)
    want = ref_sdpa(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_noncausal():
    q, k, v = make(1, 64, 128, 2, 2, 64, seed=5)
    got = flash_attention(q, k, v, causal=False, bq=32, bk=64,
                          interpret=True)
    want = ref_sdpa(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16_inputs():
    q, k, v = make(1, 128, 128, 4, 4, 64, dtype=jnp.bfloat16, seed=7)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                          interpret=True)
    want = ref_sdpa(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)

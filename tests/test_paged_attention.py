"""Paged decode-attention regression tests: q_len=1 against a long paged
cache (Pallas interpret kernel vs jnp oracle vs the dense SDPA path), page
pool quantization round-trips, and paged-vs-dense engine equivalence."""
from types import SimpleNamespace

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.kernels.paged_attn import (paged_decode_attention,
                                      paged_decode_attention_ref)
from repro.models import attention as attn
from repro.models import transformer
from repro.serving import kv_pool


def make_pool_and_dense(b, t, nkv, hd, page, seed=0, kv_bits=8):
    """A paged pool holding the same K/V as a dense (B,T,nkv,hd) cache."""
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(b, t, nkv, hd)).astype(np.float32)
    v = rng.normal(size=(b, t, nkv, hd)).astype(np.float32)
    n_seq_pages = -(-t // page)
    n_pages = 1 + b * n_seq_pages            # page 0 = scratch
    geom = SimpleNamespace(n_kv_heads=nkv, hd=hd)
    pool = kv_pool.init_pool(geom, n_pages, page, kv_bits=kv_bits)
    page_table = np.zeros((b, n_seq_pages), np.int32)
    ids = iter(range(1, n_pages))
    for i in range(b):
        page_table[i] = [next(ids) for _ in range(n_seq_pages)]
    lengths = jnp.full((b,), t, jnp.int32)
    pool = kv_pool.write_prefill(pool, jnp.asarray(k), jnp.asarray(v),
                                 jnp.asarray(page_table), lengths)
    return pool, jnp.asarray(page_table), jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("b,t,nq,nkv,hd,page", [
    (2, 128, 4, 4, 64, 16),      # MHA, long cache
    (3, 96, 8, 2, 32, 16),       # GQA 4x, ragged lengths below
    (1, 256, 4, 1, 64, 32),      # MQA, longest cache
])
@pytest.mark.parametrize("kv_bits", [16, 8, 4])
def test_paged_decode_matches_dense(b, t, nq, nkv, hd, page, kv_bits):
    """q_len=1 against a long paged cache == dense masked SDPA."""
    pool, pt, k, v = make_pool_and_dense(b, t, nkv, hd, page, kv_bits=kv_bits)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, nq, hd), jnp.float32)
    lens = jnp.asarray([t - i * (t // 4) for i in range(b)], jnp.int32)

    ks, vs = pool.get("k_s"), pool.get("v_s")
    ref = paged_decode_attention_ref(q, pool["k"], pool["v"], ks, vs, pt,
                                     lens)
    got = paged_decode_attention(q, pool["k"], pool["v"], ks, vs, pt, lens,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    # dense oracle over the *original* (unquantized) K/V
    mask = (jnp.arange(t)[None, :] < lens[:, None])[:, None, None, :]
    dense = attn._sdpa(q[:, None], k, v, mask, None)[:, 0]   # (B, nq*hd)
    # quant noise grows with narrower codes: bf16 pool / int8 / packed int4
    tol = {16: 0.03, 8: 0.12, 4: 0.5}[kv_bits]
    np.testing.assert_allclose(np.asarray(got).reshape(b, -1),
                               np.asarray(dense), rtol=tol, atol=tol)


def test_paged_write_token_roundtrip():
    """Decode writes across page boundaries: pool contents must match the
    tokens written, per-page scales tracking the running absmax."""
    page, nkv, hd, b = 8, 2, 16, 2
    geom = SimpleNamespace(n_kv_heads=nkv, hd=hd)
    pool = kv_pool.init_pool(geom, 6, page, kv_bits=8)
    pt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    toks = []
    for pos in range(2 * page):
        k = jax.random.normal(jax.random.PRNGKey(pos), (b, nkv, hd)) * (
            1.0 + pos)                        # growing absmax -> requant
        toks.append(k)
        pool = kv_pool.write_token(pool, pt, jnp.full((b,), pos, jnp.int32),
                                   k, k)
    kc, _ = kv_pool.gather_kv(pool, pt)
    want = jnp.stack(toks, 1)                 # (B, T, nkv, hd)
    err = float(jnp.max(jnp.abs(kc.astype(jnp.float32) -
                                want.astype(jnp.float32))))
    # re-rounding drift across successive requants is bounded by a few
    # final-scale quantization steps (scale grows monotonically here)
    step = 2.0 * float(jnp.max(jnp.abs(want))) / 255.0
    assert err < 4 * step, (err, step)


def test_paged_engine_matches_dense_decode():
    """Full-model paged decode (fp16 pool) == the dense decode_step path."""
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, s, page = 2, 12, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)

    # dense path
    pre = {"tokens": toks[:, :s - 1]}
    l16, caches = transformer.prefill(params, pre, cfg, max_len=s + 4)
    pos = jnp.full((b,), s - 1, jnp.int32)
    d_dense, _ = transformer.decode_step(params, caches, toks[:, s - 1],
                                         pos, cfg)

    # paged path: prefill into pages, then one paged decode step
    n_seq_pages = 4
    pools = transformer.init_paged_pools(cfg, 1 + b * n_seq_pages, page,
                                         kv_bits=16)
    pt = np.zeros((b, n_seq_pages), np.int32)
    ids = iter(range(1, 1 + b * n_seq_pages))
    for i in range(b):
        pt[i] = [next(ids) for _ in range(n_seq_pages)]
    bucket = page * (-(-(s - 1) // page))
    ptoks = np.zeros((b, bucket), np.int32)
    ptoks[:, :s - 1] = np.asarray(toks[:, :s - 1])
    lens = jnp.full((b,), s - 1, jnp.int32)
    _, dense_caches = transformer.prefill(
        params, {"tokens": jnp.asarray(ptoks), "lengths": lens}, cfg,
        max_len=bucket)
    rows = jnp.asarray(pt[:, :bucket // page])
    for i in pools:
        pools[i] = jax.vmap(kv_pool.write_prefill,
                            in_axes=(0, 0, 0, None, None))(
            pools[i], dense_caches[i]["k"], dense_caches[i]["v"], rows, lens)
    d_paged, _ = transformer.decode_step_paged(
        params, pools, jnp.asarray(pt), toks[:, s - 1], pos, cfg)
    np.testing.assert_allclose(np.asarray(d_paged), np.asarray(d_dense),
                               rtol=2e-2, atol=2e-2)

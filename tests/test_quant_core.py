"""Unit tests for the PTQ core: scales, packing, smooth, hadamard, qlinear."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.quant import (qtypes, smooth, hadamard, qlinear,
                              QuantConfig, INT8, W4A8, W4A8_SMOOTH,
                              W4A8_HADAMARD, preset)


def rng(seed=0):
    return np.random.default_rng(seed)


# -- scale / quantize -------------------------------------------------------

def test_paper_scale_formula():
    absmax = jnp.asarray([2.0, 0.0, 10.0])
    s8 = qtypes.paper_scale(absmax, 8)
    np.testing.assert_allclose(np.asarray(s8), [4 / 255, 1e-8, 20 / 255],
                               rtol=1e-6)


def test_quantize_weight_per_channel_int8_error_bound():
    r = rng(1)
    w = jnp.asarray(r.normal(0, 0.05, (256, 512)), jnp.float32)
    qt = qtypes.quantize_weight(w, INT8)
    assert qt.data.dtype == jnp.int8 and qt.scale.shape == (1, 512)
    err = np.abs(np.asarray(qt.dequantize() - w))
    bound = np.asarray(qt.scale) * 0.5 + 1e-9
    assert (err <= bound + 1e-7).all()


def test_quantize_weight_w4_group_packed():
    r = rng(2)
    w = jnp.asarray(r.normal(0, 0.05, (256, 128)), jnp.float32)
    qt = qtypes.quantize_weight(w, W4A8)
    assert qt.data.shape == (128, 128) and qt.layout == "halves"
    assert qt.scale.shape == (2, 128)
    assert qt.shape == (256, 128)
    # unpacked values stay in int4 range
    u = np.asarray(qt.unpacked())
    assert u.min() >= -8 and u.max() <= 7
    err = np.abs(np.asarray(qt.dequantize() - w))
    # per-group scale * 0.5 bound
    s = np.asarray(qt.scale).repeat(128, 0)
    assert (err <= 0.5 * s + 1e-7).all()


def test_int4_pack_unpack_interleave_roundtrip():
    r = rng(3)
    x = jnp.asarray(r.integers(-8, 8, (64, 32)).astype(np.int8))
    p = qtypes.pack_int4(x, 0)
    assert p.shape == (32, 32)
    np.testing.assert_array_equal(np.asarray(qtypes.unpack_int4(p, 0, 64)),
                                  np.asarray(x))


# -- smooth -----------------------------------------------------------------

def test_smooth_exactness_in_fp():
    """(X/s)(sW) == XW up to fp error, and smoothing reduces act outliers."""
    r = rng(4)
    x = np.asarray(r.normal(0, 1, (64, 128)), np.float32)
    x[:, 7] *= 50.0  # outlier channel
    w = np.asarray(r.normal(0, 0.05, (128, 96)), np.float32)
    a_max = np.abs(x).max(0)
    w_max = np.abs(w).max(1)
    s = smooth.smooth_scales(jnp.asarray(a_max), jnp.asarray(w_max), 0.5)
    y0 = x @ w
    y1 = (x / np.asarray(s)) @ np.asarray(smooth.apply_to_weight(jnp.asarray(w), s))
    np.testing.assert_allclose(y1, y0, rtol=1e-4, atol=1e-4)
    assert np.abs(x / np.asarray(s)).max() < np.abs(x).max() / 3


def test_smooth_squared_relu_fold_exact():
    r = rng(5)
    x = np.asarray(r.normal(0, 1, (32, 64)), np.float32)
    w_in = np.asarray(r.normal(0, 0.1, (64, 96)), np.float32)
    s = np.asarray(rng(6).uniform(0.5, 4.0, (96,)), np.float32)
    h0 = np.maximum(x @ w_in, 0) ** 2 / s
    w_in_f = np.asarray(smooth.fold_into_prev_linear_squared_relu(
        jnp.asarray(w_in), jnp.asarray(s)))
    h1 = np.maximum(x @ w_in_f, 0) ** 2
    np.testing.assert_allclose(h1, h0, rtol=1e-4, atol=1e-6)


# -- hadamard ---------------------------------------------------------------

def test_hadamard_matrix_orthogonal():
    for n in (2, 64, 128):
        h = np.asarray(hadamard.hadamard_matrix(n))
        np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-5)


def test_fwht_equals_matmul():
    r = rng(7)
    x = jnp.asarray(r.normal(0, 1, (16, 512)), jnp.float32)
    np.testing.assert_allclose(np.asarray(hadamard.block_fwht(x, 128)),
                               np.asarray(hadamard.block_hadamard_matmul(x, 128)),
                               rtol=1e-4, atol=1e-5)


def test_rotation_preserves_product():
    r = rng(8)
    x = jnp.asarray(r.normal(0, 1, (32, 256)), jnp.float32)
    w = jnp.asarray(r.normal(0, 0.05, (256, 64)), jnp.float32)
    xr = hadamard.block_hadamard_matmul(x, 128)
    wr = hadamard.rotate_weight(w, 128)
    np.testing.assert_allclose(np.asarray(xr @ wr), np.asarray(x @ w),
                               rtol=1e-3, atol=1e-4)


def test_block_size_fallback():
    assert hadamard.block_size_for(384, 128) == 128
    assert hadamard.block_size_for(96, 128) == 32
    assert hadamard.block_size_for(100, 128) == 4


# -- qlinear ----------------------------------------------------------------

def _make_qparams(w, cfg, act_absmax=None):
    p = {}
    wq_input = jnp.asarray(w)
    if cfg.smooth:
        a = jnp.asarray(act_absmax)
        s = smooth.smooth_scales(a, jnp.max(jnp.abs(wq_input), axis=1), cfg.smooth_alpha)
        wq_input = smooth.apply_to_weight(wq_input, s)
        p["smooth"] = s
    if cfg.hadamard:
        wq_input = hadamard.rotate_weight(wq_input, cfg.hadamard_block)
    p["w_q"] = qtypes.quantize_weight(wq_input, cfg)
    return p


@pytest.mark.parametrize("cfg", [INT8, W4A8, W4A8_SMOOTH, W4A8_HADAMARD])
def test_qlinear_int_matches_fake(cfg):
    r = rng(9)
    x = jnp.asarray(r.normal(0, 1, (16, 256)), jnp.float32)
    w = r.normal(0, 0.05, (256, 128)).astype(np.float32)
    p = _make_qparams(w, cfg, act_absmax=np.abs(np.asarray(x)).max(0))
    y_int = qlinear.apply(p, x, cfg, impl="xla")
    y_fake = qlinear.apply(p, x, cfg, impl="fake")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_fake),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("cfg", [INT8, W4A8_SMOOTH, W4A8_HADAMARD])
def test_qlinear_close_to_fp(cfg):
    r = rng(10)
    x = jnp.asarray(r.normal(0, 1, (64, 512)), jnp.float32)
    w = r.normal(0, 0.05, (512, 256)).astype(np.float32)
    p_fp = {"w": jnp.asarray(w)}
    p_q = _make_qparams(w, cfg, act_absmax=np.abs(np.asarray(x)).max(0))
    y_fp = qlinear.apply(p_fp, x)
    y_q = qlinear.apply(p_q, x, cfg, impl="xla")
    rel = np.linalg.norm(np.asarray(y_q - y_fp)) / np.linalg.norm(np.asarray(y_fp))
    # 4-bit gaussian weights: expected elementwise SQNR ~= 2*absmax/(15*2*std)
    # ~= 0.12 relative; 8-bit ~16x finer.
    assert rel < (0.02 if cfg.weight_bits == 8 else 0.15), rel


def test_qlinear_int8_outliers_smooth_helps():
    """SmoothQuant must reduce W8A8 error on outlier-heavy activations
    (the paper's Fig. 1 / Table 2 mechanism)."""
    r = rng(11)
    x = np.asarray(r.normal(0, 1, (64, 512)), np.float32)
    x[:, ::37] *= 30.0
    xj = jnp.asarray(x)
    w = r.normal(0, 0.05, (512, 256)).astype(np.float32)
    y_fp = np.asarray(qlinear.apply({"w": jnp.asarray(w)}, xj))

    cfgs = {"plain": W4A8, "smooth": W4A8_SMOOTH, "hadamard": W4A8_HADAMARD}
    errs = {}
    for name, cfg in cfgs.items():
        p = _make_qparams(w, cfg, act_absmax=np.abs(x).max(0))
        y = np.asarray(qlinear.apply(p, xj, cfg, impl="xla"))
        errs[name] = np.linalg.norm(y - y_fp) / np.linalg.norm(y_fp)
    assert errs["smooth"] < errs["plain"], errs
    assert errs["hadamard"] < errs["plain"], errs


def test_qlinear_bias_and_dtype():
    r = rng(12)
    x = jnp.asarray(r.normal(0, 1, (8, 128)), jnp.bfloat16)
    w = r.normal(0, 0.05, (128, 64)).astype(np.float32)
    p = _make_qparams(w, INT8)
    p["b"] = jnp.asarray(r.normal(0, 1, (64,)), jnp.float32)
    y = qlinear.apply(p, x, INT8, impl="xla")
    assert y.dtype == jnp.bfloat16 and y.shape == (8, 64)

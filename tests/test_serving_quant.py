"""Serving-path quantization tests: int8 KV cache fidelity, quantized
prefill/decode equivalence, engine with variable-length batches, and the
speculative-verify path against a dense fp32 oracle for every paged pool
dtype (bf16 / int8 / packed int4)."""
from types import SimpleNamespace

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core.quant import INT8, calibrate, ptq
from repro.kernels.paged_prefill import paged_verify_attention
from repro.models import attention as attn
from repro.models import transformer
from repro.serving import ServingEngine, kv_pool


def setup(arch="qwen3_0_6b", s=16, b=2):
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    return cfg, params, toks


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "mixtral_8x7b"])
def test_int8_kv_cache_close_to_fp(arch):
    """decode with the int8-quantized KV cache stays close to the bf16
    cache (beyond-paper W8A8KV8 path used by the 90B decode cells)."""
    cfg, params, toks = setup(arch)
    b, s = toks.shape
    pre = {"tokens": toks[:, :s - 1]}
    last = toks[:, s - 1]
    pos = jnp.full((b,), s - 1, jnp.int32)

    l16, c16 = transformer.prefill(params, pre, cfg, max_len=s + 2,
                                   kv_bits=16)
    d16, _ = transformer.decode_step(params, c16, last, pos, cfg)
    l8, c8 = transformer.prefill(params, pre, cfg, max_len=s + 2, kv_bits=8)
    d8, _ = transformer.decode_step(params, c8, last, pos, cfg)
    # logits close; top-1 identical for a random-init model's margins
    np.testing.assert_allclose(np.asarray(d8), np.asarray(d16), atol=0.15,
                               rtol=0.1)
    agree = float(jnp.mean(jnp.argmax(d8, -1) == jnp.argmax(d16, -1)))
    assert agree >= 0.5, agree


def test_rolling_window_cache_decode_matches_forward():
    """SWA rolling cache beyond the window: decode at pos > window must
    equal the full forward with window masking (mixtral long-context)."""
    cfg = reduced(get_arch("mixtral_8x7b"))
    assert cfg.sliding_window and cfg.sliding_window < 64
    w = cfg.sliding_window
    s = w * 2 + 5              # sequence well past the window
    params = transformer.init_params(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, s), 0, cfg.vocab)

    logits_full, _ = transformer.forward_train(params, {"tokens": toks},
                                               cfg, remat=False)
    pre = {"tokens": toks[:, :s - 1]}
    _, caches = transformer.prefill(params, pre, cfg, max_len=s + 2)
    pos = jnp.full((1,), s - 1, jnp.int32)
    dec, _ = transformer.decode_step(params, caches, toks[:, s - 1], pos, cfg)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(logits_full[:, s - 1]),
                               rtol=3e-2, atol=3e-2)


def test_engine_variable_length_prompts_quantized():
    cfg, params, _ = setup("qwen3_0_6b")
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(9), (2, 24),
                                             0, cfg.vocab)}]
    stats = calibrate.collect_stats(params, batches, cfg)
    pq = ptq.quantize_model(params, cfg, INT8, stats)
    eng = ServingEngine(pq, cfg, qcfg=INT8, impl="xla")
    prompts = [[5, 6, 7], list(range(1, 20)), [9] * 11]
    res = eng.generate(prompts, max_new=6, mode="slow_think")
    assert len(res.tokens) == 3
    assert all(len(t) == 6 for t in res.tokens)
    assert all(0 <= tok < cfg.vocab for t in res.tokens for tok in t)


# ---------------------------------------------------------------------------
# speculative verify vs dense fp32, all paged pool dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [16, 8, 4])
def test_paged_verify_matches_dense_fp32(kv_bits):
    """The verify path (raw draft window spliced over a quantized paged
    history, ragged n_new) reproduces dense fp32 causal attention within
    the pool dtype's quantization noise — only the history round-trips
    through pages, so the bound is the same family as the decode kernel's."""
    b, c, nq, nkv, hd, page = 2, 4, 4, 2, 16, 8
    q_start = np.asarray([13, 7], np.int32)       # unaligned page boundaries
    n_new = np.asarray([4, 3], np.int32)          # one lane partially idle
    t = int(q_start.max()) + c
    w = -(-t // page)
    bucket = w * page                             # write_prefill page bucket
    rng = np.random.default_rng(5)

    # history raw K/V, zeroed past each row's q_start (write_prefill masks
    # by lengths too; the oracle below needs the same zeros)
    hist_k = rng.normal(size=(b, bucket, nkv, hd)).astype(np.float32)
    hist_v = rng.normal(size=(b, bucket, nkv, hd)).astype(np.float32)
    live = (np.arange(bucket)[None, :, None, None]
            < q_start[:, None, None, None])
    hist_k, hist_v = hist_k * live, hist_v * live
    k_win = rng.normal(size=(b, c, nkv, hd)).astype(np.float32)
    v_win = rng.normal(size=(b, c, nkv, hd)).astype(np.float32)
    q = rng.normal(size=(b, c, nq, hd)).astype(np.float32)

    geom = SimpleNamespace(n_kv_heads=nkv, hd=hd)
    pool = kv_pool.init_pool(geom, 1 + b * w, page, kv_bits=kv_bits)
    pt = np.arange(1, 1 + b * w, dtype=np.int32).reshape(b, w)
    pool = kv_pool.write_prefill(pool, jnp.asarray(hist_k),
                                 jnp.asarray(hist_v), jnp.asarray(pt),
                                 jnp.asarray(q_start))

    got = np.asarray(paged_verify_attention(
        jnp.asarray(q), pool["k"], pool["v"], pool.get("k_s"),
        pool.get("v_s"), jnp.asarray(pt), jnp.asarray(q_start),
        jnp.asarray(n_new), jnp.asarray(k_win), jnp.asarray(v_win)))

    # dense fp32 oracle: splice the raw window over raw history
    hper = nq // nkv
    tol = {16: 0.03, 8: 0.12, 4: 0.5}[kv_bits]
    for i in range(b):
        keys, vals = hist_k[i].copy(), hist_v[i].copy()
        keys[q_start[i]:q_start[i] + c] = k_win[i]
        vals[q_start[i]:q_start[i] + c] = v_win[i]
        kr = np.repeat(keys, hper, axis=1)        # (bucket, nq, hd)
        vr = np.repeat(vals, hper, axis=1)
        scores = np.einsum("cqh,tqh->qct", q[i] / hd ** 0.5, kr)
        kpos = np.arange(bucket)[None, None, :]
        qpos = (q_start[i] + np.arange(c))[None, :, None]
        mask = (kpos <= qpos) & (kpos < q_start[i] + n_new[i])
        scores = np.where(mask, scores, -1e30)
        probs = np.exp(scores - scores.max(-1, keepdims=True))
        probs /= probs.sum(-1, keepdims=True)
        want = np.einsum("qct,tqh->cqh", probs, vr)
        # rows past n_new are masked lanes with garbage-by-contract outputs
        np.testing.assert_allclose(got[i, :n_new[i]], want[:n_new[i]],
                                   rtol=tol, atol=tol)


def test_decode_mask_rolling_positions():
    """Rolling-slot position recovery: slots hold the right absolute keys."""
    c = {"k": jnp.zeros((2, 8, 1, 4)), "v": jnp.zeros((2, 8, 1, 4))}
    m = attn.decode_mask(c, jnp.array([10, 3]), window=8)[:, 0, 0]
    # request 0 at pos 10, window 8: valid keys are pos 3..10 -> all slots
    assert bool(m[0].all())
    # request 1 at pos 3: only slots 0..3 valid (pos 0..3)
    np.testing.assert_array_equal(
        np.asarray(m[1]), [True, True, True, True, False, False, False,
                           False])

"""Continuous-batching scheduler unit tests: admission, completion, page
reclaim, preemption, and no cross-sequence leakage through the shared pool."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.models import transformer
from repro.serving import ContinuousBatchingEngine, ServingEngine
from repro.serving.kv_pool import SCRATCH_PAGE, PageAllocator
from repro.serving.scheduler import PagedScheduler, Request


def mk_req(rid, n, budget=4):
    return Request(rid=rid, prompt=list(range(1, n + 1)), mode="slow_think",
                   budget=budget)


def test_allocator_free_list_reuse():
    a = PageAllocator(6)                      # pages 1..5 allocatable
    got = a.alloc(5)
    assert sorted(got) == [1, 2, 3, 4, 5] and a.alloc(1) is None
    a.free(got[:2])
    assert sorted(a.alloc(2)) == sorted(got[:2])
    with pytest.raises(AssertionError):
        a.free([SCRATCH_PAGE])


def test_allocator_rejects_double_free_and_bad_ids():
    """The two pool-corrupting bugs fail fast: freeing a page twice (it
    would re-enter the free list while a live sequence still maps it) and
    freeing an id outside 1..n_pages-1 (a stale page-table row)."""
    a = PageAllocator(6)
    got = a.alloc(3)
    a.free(got[:1])
    with pytest.raises(AssertionError, match="double free"):
        a.free(got[:1])                       # already back in the list
    with pytest.raises(AssertionError, match="double free"):
        a.free(got[1:2] + got[1:2])           # twice in one call
    # state stays consistent: pages 1 and 2 went back, 3 is still out
    assert a.n_free == 4 and sorted(a.alloc(4)) == [1, 2, 4, 5]
    with pytest.raises(AssertionError, match="out of range"):
        a.free([6])
    with pytest.raises(AssertionError, match="out of range"):
        a.free([-1])
    with pytest.raises(AssertionError):
        a.free([SCRATCH_PAGE])


def test_admission_respects_slots_and_pages():
    s = PagedScheduler(n_slots=2, n_pages=5, page_size=4, max_pages_per_seq=4)
    for rid, n in enumerate([8, 4, 4]):       # 2, 1, 1 pages
        s.submit(mk_req(rid, n))
    admitted = s.admit()
    # slots bound admission to 2 even though pages remain for the third
    assert [r.rid for _, r in admitted] == [0, 1]
    assert s.alloc.n_free == 1 and len(s.waiting) == 1
    # page table rows populated, scratch elsewhere
    for slot, req in admitted:
        need = -(-len(req.prompt) // 4)
        assert (s.page_table[slot, :need] != SCRATCH_PAGE).all()
        assert (s.page_table[slot, need:] == SCRATCH_PAGE).all()


def test_completion_reclaims_pages_and_slot():
    s = PagedScheduler(n_slots=1, n_pages=4, page_size=4, max_pages_per_seq=3)
    s.submit(mk_req(0, 12))                   # all 3 pages
    [(slot, _)] = s.admit()
    assert s.alloc.n_free == 0 and not s.admit()
    s.submit(mk_req(1, 12))
    s.complete(slot)
    assert s.alloc.n_free == 3
    assert (s.page_table[slot] == SCRATCH_PAGE).all() and s.lengths[slot] == 0
    # freed pages admit the waiting request immediately
    assert [r.rid for _, r in s.admit()] == [1]


def test_decode_capacity_growth_and_preemption():
    s = PagedScheduler(n_slots=2, n_pages=4, page_size=4, max_pages_per_seq=3)
    s.submit(mk_req(0, 3))
    s.submit(mk_req(1, 3))
    s.admit()                                 # one page each, one free
    # seq 0 crosses a page boundary -> grows from the free list
    s.lengths[0] = 4
    assert s.ensure_decode_capacity() == []
    assert len(s.seq_pages[0]) == 2 and s.alloc.n_free == 0
    # seq 1 crosses next: pool dry -> the *newest* active is preempted, and
    # seq 1 is itself the newest: it yields instead of starving seq 0
    s.lengths[1] = 4
    evicted = s.ensure_decode_capacity()
    assert [r.rid for r in evicted] == [1]
    assert evicted[0].out == [] and evicted[0].preemptions == 1
    assert s.waiting[0].rid == 1              # requeued at the front
    assert len(s.seq_pages[0]) == 2 and 0 in s.active and 1 not in s.active
    # with seq 1 gone its pages are free again: a re-admitted seq 1 whose
    # growth hits a dry pool is now the victim of choice for seq 0
    [(slot1, _)] = s.admit()
    s.lengths[slot1] = 2
    s.lengths[0] = 8                          # needs a third page
    evicted = s.ensure_decode_capacity()
    assert [r.rid for r in evicted] == [1]    # oldest keeps progressing
    assert len(s.seq_pages[0]) == 3


def test_no_cross_sequence_leakage():
    """Concurrent requests through the shared pool generate exactly what
    they generate alone — pages can't bleed across sequences, including
    after completion frees pages mid-flight for reuse."""
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[7, 8, 9], list(range(1, 18)), [4] * 9, [11, 3, 5, 2]]
    budgets = [3, 12, 6, 9]                   # staggered completions

    solo = []
    for p, n in zip(prompts, budgets):
        eng = ContinuousBatchingEngine(params, cfg, kv_bits=16, page_size=8,
                                       max_batch=1, max_seq_len=64)
        solo.append(eng.run([p], max_new=n).tokens[0])

    eng = ContinuousBatchingEngine(params, cfg, kv_bits=16, page_size=8,
                                   max_batch=4, max_seq_len=64)
    for p, n in zip(prompts, budgets):
        eng.submit(p, max_new=n)
    while not eng.sched.idle:
        eng.step()
    together = [eng._requests[r].out for r in range(4)]
    assert together == solo


def test_continuous_matches_legacy_engine():
    """The paged engine (fp16 pool) reproduces the legacy dense engine."""
    cfg = reduced(get_arch("qwen3_0_6b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[5, 6, 7], list(range(1, 20)), [9] * 11]
    ref = ServingEngine(params, cfg).generate(prompts, max_new=6,
                                              mode="no_think")
    eng = ContinuousBatchingEngine(params, cfg, kv_bits=16, page_size=8,
                                   max_batch=3, max_seq_len=64)
    res = eng.run(prompts, mode="no_think", max_new=6)
    assert res.tokens == ref.tokens


def test_preemption_preserves_outputs():
    """A pool too small for all sequences at once: requests are evicted and
    recomputed, but every request still finishes with the same tokens."""
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[5, 6, 7], list(range(1, 20)), [9] * 11, [3, 1, 4, 1, 5]]

    roomy = ContinuousBatchingEngine(params, cfg, kv_bits=8, page_size=8,
                                     max_batch=4, max_seq_len=64)
    want = roomy.run(prompts, max_new=8).tokens
    tight = ContinuousBatchingEngine(params, cfg, kv_bits=8, page_size=8,
                                     max_batch=4, max_seq_len=64, n_pages=9)
    res = tight.run(prompts, max_new=8)
    assert res.evictions > 0
    assert res.tokens == want


def test_int8_pool_close_to_fp16_pool():
    """Paged int8 KV decode stays close to the fp16-pool decode (and the
    pool really is ~half the bytes)."""
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [list(range(1, 14)), [8] * 6]
    engines = {}
    for kv_bits in (16, 8):
        engines[kv_bits] = ContinuousBatchingEngine(
            params, cfg, kv_bits=kv_bits, page_size=8, max_batch=2,
            max_seq_len=64)
    r16 = engines[16].run(prompts, max_new=10)
    r8 = engines[8].run(prompts, max_new=10)
    agree = np.mean([a == b for t16, t8 in zip(r16.tokens, r8.tokens)
                     for a, b in zip(t16, t8)])
    assert agree >= 0.5, agree
    ratio = engines[8].kv_bytes_per_token() / engines[16].kv_bytes_per_token()
    assert ratio <= 0.55, ratio

"""Substrate tests: data determinism/skip-ahead, optimizer, trainer
(learning + microbatch equivalence + compressed DP), serving engine + CoT,
checkpoint save/restore/elastic."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import transformer
from repro.optim import adamw
from repro.serving import ServingEngine, cot
from repro.train import trainer


def tiny_setup(arch="pangu_1b", seed=0):
    cfg = reduced(get_arch(arch))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, seed=seed))
    return cfg, data


# -- data ---------------------------------------------------------------------

def test_data_deterministic_and_skip_ahead():
    _, data = tiny_setup()
    b1 = data.batch(5, 4)
    b2 = data.batch(5, 4)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = data.batch(6, 4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted stream
    assert b1["tokens"].shape == b1["labels"].shape == (4, 32)
    # host sharding decorrelates
    h0 = data.batch(5, 4, host_id=0, num_hosts=2)
    h1 = data.batch(5, 4, host_id=1, num_hosts=2)
    assert not np.array_equal(np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"]))


def test_data_is_learnable_markov():
    """The stream must be lower-entropy than uniform (so training can show
    measurable ppl drop for the fidelity benchmarks)."""
    cfg, data = tiny_setup()
    b = data.batch(0, 8)
    succ = np.asarray(data.succ)
    toks, labs = np.asarray(b["tokens"]), np.asarray(b["labels"])
    ok = np.zeros_like(labs, bool)
    for br in range(succ.shape[1]):
        ok |= succ[toks, br] == labs
    assert ok.mean() > 0.99  # every label is one of `branching` successors


# -- optimizer / trainer --------------------------------------------------------

def test_adamw_descends_quadratic():
    p = {"w": jnp.ones((8,)) * 5.0}
    ocfg = adamw.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                           weight_decay=0.0)
    st = adamw.init(p)
    for _ in range(50):
        g = {"w": 2 * p["w"]}
        p, st, m = adamw.update(g, st, p, ocfg)
    assert float(jnp.abs(p["w"]).max()) < 1.0


def test_train_step_learns():
    cfg, data = tiny_setup()
    ocfg = adamw.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    state = trainer.init_state(jax.random.PRNGKey(0), cfg, ocfg)
    step = jax.jit(trainer.make_train_step(cfg, ocfg, remat=False))
    losses = []
    for i in range(40):
        state, metrics = step(state, data.batch(i, 8))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::8]


def test_microbatch_equivalent_to_full():
    """Accumulated microbatch grads == full-batch grads (up to bf16 fusion
    reassociation). Post-Adam params are NOT compared: m/sqrt(v) is sign-
    sensitive for near-zero grads, so fp noise there is amplified to ~lr."""
    cfg, data = tiny_setup()
    batch = data.batch(0, 8)
    params = transformer.init_params(jax.random.PRNGKey(1), cfg)

    def loss_fn(p, b):
        return transformer.lm_loss(p, b, cfg, remat=False)[0]

    g_full = jax.grad(loss_fn)(params, batch)
    micro = jax.tree.map(lambda x: x.reshape(4, 2, *x.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, g_full)
    losses = []
    for i in range(4):
        mb = jax.tree.map(lambda x: x[i], micro)
        g = jax.grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
    g_acc = jax.tree.map(lambda g: g / 4, g_acc)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.05, atol=2e-4)


# -- serving -------------------------------------------------------------------

def test_engine_generates_and_modes_differ():
    cfg, data = tiny_setup()
    params = transformer.init_params(jax.random.PRNGKey(2), cfg)
    eng = ServingEngine(params, cfg)
    prompts = [[1, 2, 3, 4], list(range(40))]  # short + long prompt
    study = eng.cot_study(prompts, max_new=16)
    assert set(study) == set(cot.MODES)
    assert study["no_think"]["mean_len"] < study["slow_think"]["mean_len"]
    # auto_think: short prompt -> condensed, long prompt -> full
    auto = study["auto_think"]["generations"]
    assert len(auto[0]) < len(auto[1])
    for mode in cot.MODES:
        for g in study[mode]["generations"]:
            assert all(0 <= t < cfg.vocab for t in g)


def test_repetition_detector():
    assert cot.detect_repetition([1, 2, 3] + [7, 8] * 8)
    assert cot.detect_repetition([5] * 20, max_phrase=4)
    assert not cot.detect_repetition(list(range(40)))
    assert not cot.detect_repetition([1, 2, 1, 3, 1, 4, 1, 5, 1, 6])


# -- checkpoint ------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg, data = tiny_setup()
    ocfg = adamw.OptConfig()
    state = trainer.init_state(jax.random.PRNGKey(3), cfg, ocfg)
    ck = Checkpointer(str(tmp_path), keep_last=2)
    for s in (1, 2, 3):
        ck.save(s, state, blocking=(s != 3))
    ck.wait()
    assert ck.latest_step() == 3
    assert ck.all_steps() == [2, 3]  # gc dropped step 1
    restored = ck.restore(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto a different device layout (elastic): simulate with a
    1-device NamedSharding target."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    cfg, _ = tiny_setup()
    params = transformer.init_params(jax.random.PRNGKey(4), cfg)
    ck = Checkpointer(str(tmp_path))
    ck.save(7, params, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    restored = ck.restore(params, shardings=sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_training_continuity(tmp_path):
    """Save mid-run, restore, continue: loss trajectory must continue from
    the checkpoint (exact same data via skip-ahead)."""
    cfg, data = tiny_setup()
    ocfg = adamw.OptConfig(lr=3e-3, warmup_steps=0, total_steps=50)
    step = jax.jit(trainer.make_train_step(cfg, ocfg, remat=False))
    state = trainer.init_state(jax.random.PRNGKey(5), cfg, ocfg)
    for i in range(6):
        state, m = step(state, data.batch(i, 4))
        if i == 2:
            ck = Checkpointer(str(tmp_path))
            ck.save(3, state, blocking=True)
    ref_loss = float(m["loss"])
    # resume from step 3 and replay steps 3..5
    state2 = ck.restore(state)
    for i in range(3, 6):
        state2, m2 = step(state2, data.batch(i, 4))
    np.testing.assert_allclose(float(m2["loss"]), ref_loss, rtol=1e-4)

"""Chunked-prefill regression tests: fused quantize-on-write page writes
(`kv_pool.write_chunk` vs the one-shot and per-token paths) across all pool
dtypes (bf16/int8/packed-int4), the chunk attention kernel (Pallas
interpret vs jnp oracle vs dense causal SDPA), and chunked-vs-one-shot
engine equivalence including preemption mid-prefill."""
from types import SimpleNamespace

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import make_engine, pool_leaves
from repro.kernels.paged_prefill import (paged_prefill_attention,
                                         paged_prefill_attention_ref)
from repro.models import attention as attn
from repro.serving import kv_pool


def _geom(nkv, hd):
    return SimpleNamespace(n_kv_heads=nkv, hd=hd)


def _pool_with_tables(b, n_seq_pages, page, nkv, hd, kv_bits):
    pool = kv_pool.init_pool(_geom(nkv, hd), 1 + b * n_seq_pages, page,
                             kv_bits=kv_bits)
    pt = np.arange(1, 1 + b * n_seq_pages, dtype=np.int32).reshape(
        b, n_seq_pages)
    return pool, jnp.asarray(pt)


# ---------------------------------------------------------------------------
# write_prefill edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [16, 1, 5])   # exact page multiple, single, odd
def test_write_prefill_edge_cases(kv_bits, n):
    """Page-multiple prompts, a length-1 prompt, and scratch-padded bucket
    rows all round-trip: valid positions match, padding cannot leak into
    scales, scratch-row writes are zeros."""
    page, nkv, hd, b = 8, 2, 16, 1
    s = 4 * page                                   # bucket > needed pages
    rng = np.random.default_rng(n)
    k = rng.normal(size=(b, s, nkv, hd)).astype(np.float32)
    k[:, n:] = 37.0                                # garbage beyond length
    pool, _ = _pool_with_tables(b, 4, page, nkv, hd, kv_bits)
    need = -(-n // page)
    rows = np.full((b, 4), kv_pool.SCRATCH_PAGE, np.int32)
    rows[0, :need] = range(1, 1 + need)
    pool = kv_pool.write_prefill(pool, jnp.asarray(k), jnp.asarray(k),
                                 jnp.asarray(rows),
                                 jnp.full((b,), n, jnp.int32))
    full = jnp.asarray(np.arange(1, 5, dtype=np.int32)[None, :])
    kc, _ = kv_pool.gather_kv(pool, full)
    got = np.asarray(kc, np.float32)
    am = float(np.abs(k[:, :n]).max())
    # quantized pools: one page-scale step of error (clip at the extremes)
    tol = {16: 0.02, 8: 2 * am / 255, 4: 2 * am / 15}[kv_bits]
    np.testing.assert_allclose(got[:, :n], k[:, :n], atol=tol)
    # positions past the length were zeroed before quantization: the 37s
    # can't inflate the page scale or survive in the pool
    if n < need * page:
        assert np.abs(got[:, n:need * page]).max() == 0.0
    # pages beyond the allocation were never written (rows were scratch)
    assert np.abs(got[:, need * page:]).max() == 0.0


# ---------------------------------------------------------------------------
# write_chunk vs the one-shot and per-token write paths
# ---------------------------------------------------------------------------

def test_write_chunk_matches_write_prefill(kv_bits):
    """Page-aligned chunks of a prompt land bit-identical to the one-shot
    write_prefill scatter — same quantized codes (int8 bytes or packed int4
    nibbles) *and* same per-(page, head) scales (fused quantize-on-write is
    not an approximation of the legacy two-pass path on fresh pages)."""
    page, nkv, hd, b, n = 8, 2, 16, 2, 40          # 5 pages
    c = 2 * page                                   # chunk = 2 pages
    wc = kv_pool.chunk_window_pages(c, page)
    rng = np.random.default_rng(0)
    k = rng.normal(size=(b, n, nkv, hd)).astype(np.float32)
    v = rng.normal(size=(b, n, nkv, hd)).astype(np.float32)

    ref_pool, pt = _pool_with_tables(b, 5, page, nkv, hd, kv_bits)
    ref_pool = kv_pool.write_prefill(ref_pool, jnp.asarray(k), jnp.asarray(v),
                                     pt, jnp.full((b,), n, jnp.int32))

    got_pool, _ = _pool_with_tables(b, 5, page, nkv, hd, kv_bits)
    pt_np = np.asarray(pt)
    for start in range(0, n, c):
        n_new = min(c, n - start)
        chunk_k = np.zeros((b, c, nkv, hd), np.float32) + 99.0  # garbage tail
        chunk_v = np.zeros((b, c, nkv, hd), np.float32) + 99.0
        chunk_k[:, :n_new] = k[:, start:start + n_new]
        chunk_v[:, :n_new] = v[:, start:start + n_new]
        pidx0 = start // page
        rows = np.full((b, wc), kv_pool.SCRATCH_PAGE, np.int32)
        take = min(wc, 5 - pidx0)
        rows[:, :take] = pt_np[:, pidx0:pidx0 + take]
        got_pool = kv_pool.write_chunk(
            got_pool, jnp.asarray(chunk_k), jnp.asarray(chunk_v),
            jnp.asarray(rows), jnp.full((b,), start, jnp.int32),
            jnp.full((b,), n_new, jnp.int32))

    for name in pool_leaves(kv_bits):
        np.testing.assert_array_equal(
            np.asarray(got_pool[name][1:]), np.asarray(ref_pool[name][1:]),
            err_msg=name)


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_write_chunk_decode_matches_write_token(kv_bits):
    """A riding decode slot (n_new=1 at an unaligned position) through
    write_chunk is bit-identical to the dedicated write_token path: same
    dequant (unpack for int4) -> mask -> merge -> requant semantics."""
    page, nkv, hd, b = 8, 2, 16, 2
    c = page                                       # 1-page chunks, wc = 2
    wc = kv_pool.chunk_window_pages(c, page)
    tok_pool, pt = _pool_with_tables(b, 2, page, nkv, hd, kv_bits)
    chk_pool = {k_: v_ for k_, v_ in tok_pool.items()}
    pt_np = np.asarray(pt)
    for pos in range(12):                          # crosses a page boundary
        k = np.asarray(jax.random.normal(jax.random.PRNGKey(pos),
                                         (b, nkv, hd))) * (1.0 + pos)
        kj = jnp.asarray(k)
        tok_pool = kv_pool.write_token(
            tok_pool, pt, jnp.full((b,), pos, jnp.int32), kj, kj)
        chunk = jnp.zeros((b, c, nkv, hd)).at[:, 0].set(kj) + 0.0
        pidx0 = pos // page
        rows = np.full((b, wc), kv_pool.SCRATCH_PAGE, np.int32)
        take = min(wc, 2 - pidx0)
        rows[:, :take] = pt_np[:, pidx0:pidx0 + take]
        chk_pool = kv_pool.write_chunk(
            chk_pool, chunk, chunk, jnp.asarray(rows),
            jnp.full((b,), pos, jnp.int32), jnp.ones((b,), jnp.int32))
    for name in ("k", "v", "k_s", "v_s"):
        np.testing.assert_array_equal(
            np.asarray(chk_pool[name][1:3]), np.asarray(tok_pool[name][1:3]),
            err_msg=name)


# ---------------------------------------------------------------------------
# chunk attention kernel: interpret vs oracle vs dense SDPA
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,nq,nkv,hd,page,c", [
    (2, 128, 4, 4, 64, 16, 32),      # MHA
    (3, 96, 8, 2, 32, 16, 16),       # GQA 4x
    (1, 128, 4, 1, 64, 32, 32),      # MQA
])
def test_paged_prefill_kernel_matches_ref(b, t, nq, nkv, hd, page, c,
                                          kv_bits):
    """Chunk queries at staggered q_start against a long paged cache:
    Pallas interpret == jnp oracle, both within quantization tolerance of
    the dense causal SDPA over the original K/V."""
    rng = np.random.default_rng(3)
    k = rng.normal(size=(b, t, nkv, hd)).astype(np.float32)
    v = rng.normal(size=(b, t, nkv, hd)).astype(np.float32)
    n_seq_pages = t // page
    pool, pt = _pool_with_tables(b, n_seq_pages, page, nkv, hd, kv_bits)
    pool = kv_pool.write_prefill(pool, jnp.asarray(k), jnp.asarray(v), pt,
                                 jnp.full((b,), t, jnp.int32))
    q = jax.random.normal(jax.random.PRNGKey(1), (b, c, nq, hd), jnp.float32)
    # stagger chunk starts per sequence; mix full and partial (decode) lanes
    q_start = jnp.asarray([(i * 24) % (t - c) for i in range(b)], jnp.int32)
    n_new = jnp.asarray([c if i % 2 == 0 else 1 for i in range(b)], jnp.int32)
    kv_len = q_start + n_new

    ks, vs = pool.get("k_s"), pool.get("v_s")
    ref = paged_prefill_attention_ref(q, pool["k"], pool["v"], ks, vs, pt,
                                      q_start, kv_len)
    got = paged_prefill_attention(q, pool["k"], pool["v"], ks, vs, pt,
                                  q_start, kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    # dense causal oracle over the original (unquantized) K/V
    kpos = jnp.arange(t)[None, None, :]
    qpos = (q_start[:, None] + jnp.arange(c)[None, :])[..., None]
    mask = ((kpos <= qpos) & (kpos < kv_len[:, None, None]))[:, None]
    dense = attn._sdpa(q, jnp.asarray(k), jnp.asarray(v),
                       mask.transpose(0, 1, 2, 3), None)
    # quant-noise tolerance grows ~(2^8-1)/(2^n-1) with narrower codes
    tol = {16: 0.03, 8: 0.12, 4: 0.75}[kv_bits]
    rows = np.asarray(n_new)[:, None] > np.arange(c)[None, :]  # valid rows
    d = np.abs(np.asarray(got).reshape(b, c, -1)
               - np.asarray(dense).reshape(b, c, -1)).max(-1)
    assert d[rows].max() < tol, d[rows].max()


# ---------------------------------------------------------------------------
# chunked vs one-shot prefill through the full model and engine
# ---------------------------------------------------------------------------

def _run(engine, prompts, max_new=6):
    return engine.run(prompts, mode="slow_think", max_new=max_new)


def test_chunked_engine_matches_legacy_fp16(cfg_params):
    """fp16 pools: the chunked mixed-step engine reproduces the legacy
    per-admission engine token-for-token, in exactly two steady-state
    compilations (mixed + decode, zero one-shot prefills)."""
    cfg, params = cfg_params
    prompts = [[5, 6, 7], list(range(1, 20)), [9] * 11, [3, 1, 4, 1, 5]]
    mk = dict(kv_bits=16, max_batch=4)
    leg = make_engine(params, cfg, prefill_mode="legacy", **mk)
    ch = make_engine(params, cfg, **mk)
    want, got = _run(leg, prompts), _run(ch, prompts)
    assert got.tokens == want.tokens
    assert got.prefill_tokens == sum(got.prompt_lens)
    assert got.mixed_steps > 0
    assert ch.compile_counts() == {"prefill": 0, "mixed": 1, "decode": 1,
                                   "verify": 0}


def test_chunked_engine_first_token_int8(cfg_params):
    """int8 pools: chunked prefill quantizes each chunk once into its pages
    (the legacy path quantizes the whole prompt in one pass) — identical on
    fresh aligned pages, so first sampled tokens agree."""
    cfg, params = cfg_params
    prompts = [list(range(1, 20)), [9] * 11, [3, 1, 4, 1, 5]]
    leg = make_engine(params, cfg, kv_bits=8, prefill_mode="legacy")
    ch = make_engine(params, cfg, kv_bits=8)
    want, got = _run(leg, prompts), _run(ch, prompts)
    first_leg = [t[0] for t in want.tokens]
    first_ch = [t[0] for t in got.tokens]
    # legacy computes prompt logits from the dense bf16 forward; chunked
    # reads the (re-rounded) int8 pages — allow one flip across requests
    agree = sum(a == b for a, b in zip(first_leg, first_ch))
    assert agree >= len(prompts) - 1, (first_leg, first_ch)


def test_chunked_pools_match_oneshot_pools(cfg_params, kv_bits):
    """After chunked prefill, every block's pages *and scales* (bf16 bytes,
    int8 codes, or packed int4 nibbles) equal the one-shot write_prefill of
    the same dense prompt K/V."""
    cfg, params = cfg_params
    page, n = 8, 19
    prompts = [list(range(1, n + 1))]
    mk = dict(kv_bits=kv_bits, max_batch=1, max_seq_len=32)
    leg = make_engine(params, cfg, prefill_mode="legacy", **mk)
    ch = make_engine(params, cfg, **mk)
    # run exactly the prefill portion: submit + step until the first token
    for eng in (leg, ch):
        eng.submit(prompts[0], mode="no_think", max_new=4)
        while not any(r.out for r in eng._requests.values()):
            eng.step()
    used = np.asarray(leg.sched.page_table[0][:-(-n // page)])
    assert (np.asarray(ch.sched.page_table[0][:len(used)]) == used).all()
    for blk in leg.pools:
        for name in pool_leaves(kv_bits):
            np.testing.assert_array_equal(
                np.asarray(ch.pools[blk][name][:, used]),
                np.asarray(leg.pools[blk][name][:, used]),
                err_msg=f"block {blk} {name}")


def test_preemption_mid_prefill_preserves_outputs(cfg_params, kv_bits):
    """A pool too small to hold every prompt: requests get evicted while
    *partially prefilled* (pages freed, progress reset), recomputed, and
    still finish with the roomy engine's tokens — the deterministic
    requantization on recompute makes this hold for every pool dtype."""
    cfg, params = cfg_params
    prompts = [list(range(1, 20)), [9] * 17, [3, 1, 4, 1, 5, 9, 2, 6]]
    roomy = make_engine(params, cfg, kv_bits=kv_bits)
    want = _run(roomy, prompts, max_new=8)
    tight = make_engine(params, cfg, kv_bits=kv_bits, n_pages=7)
    got = _run(tight, prompts, max_new=8)
    assert got.evictions > 0
    assert got.tokens == want.tokens

"""quantlint self-tests.

Every AST rule and every flow invariant must catch its seeded fixture
violation (tests/fixtures/quantlint/), and the real src/ tree plus the
default dtype-flow suite must pass clean — the same gate scripts/ci.sh runs.
"""
import importlib
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (FLOW_RULES, RULES, TraceSpec, check_suite,
                            check_trace, lint_file, lint_paths)
from repro.analysis.suite import default_specs

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "quantlint"


def lint_fixture(name, rules=None):
    p = FIXTURES / name
    return lint_file(p, rel=str(p), rules=rules)


def rule_ids(findings):
    return sorted({f.rule for f in findings})


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# AST rules: each fixture violation is caught
# ---------------------------------------------------------------------------

def test_rule_registry_complete():
    assert set(RULES) == {"pallas-compiler-params", "raw-compiler-params",
                          "magic-quant-literal", "no-float64",
                          "pallas-interpret"}
    assert set(FLOW_RULES) == {"int8-accum", "scale-once", "scale-mismatch",
                               "packed-int4-upcast", "nonlinear-on-unscaled"}


def test_pallas_compiler_params_rule():
    got = lint_fixture("bad_compiler_params.py",
                       rules=["pallas-compiler-params"])
    # one pallas_call with no compiler_params=, one built without the shim
    assert len(got) == 2
    assert rule_ids(got) == ["pallas-compiler-params"]


def test_raw_compiler_params_rule():
    got = lint_fixture("bad_compiler_params.py",
                       rules=["raw-compiler-params"])
    assert len(got) == 1
    assert "TPUCompilerParams" in got[0].message


def test_magic_quant_literal_rule():
    got = lint_fixture("bad_magic_literal.py", rules=["magic-quant-literal"])
    # -128 and 127 clip bounds, the int4 denominator 15, and 127.0
    assert len(got) == 4
    msgs = " ".join(f.message for f in got)
    for spelling in ("-128", "127", "15", "127.0"):
        assert spelling in msgs
    # positive bare 128 (MXU tile size) must NOT be flagged
    assert not any("128'" in f.message and "-" not in f.message for f in got)


def test_no_float64_rule():
    got = lint_fixture("bad_float64.py", rules=["no-float64"])
    # jnp.float64 attr, "float64" string, np.float64 attr
    assert len(got) == 3


def test_pallas_interpret_rule():
    got = lint_fixture("kernels/bad_interpret.py", rules=["pallas-interpret"])
    # one pallas_call without interpret=, one hardcoded without a wrapper
    # parameter; good_wrapper is clean
    assert len(got) == 2
    assert all(f.line < 40 for f in got), got


def test_pallas_interpret_rule_is_path_scoped():
    # the same rule stays silent outside kernels/ trees
    got = lint_fixture("bad_compiler_params.py", rules=["pallas-interpret"])
    assert got == []


def test_suppression_comments():
    assert lint_fixture("suppressed_ok.py") == []
    # sanity: the same code without the trailing comments would be flagged
    src = (FIXTURES / "suppressed_ok.py").read_text()
    stripped = "\n".join(line.split("#")[0] for line in src.splitlines())
    tmp = FIXTURES / "_stripped_tmp.py"
    tmp.write_text(stripped)
    try:
        got = lint_file(tmp, rel=str(tmp))
        assert "magic-quant-literal" in rule_ids(got)
        assert "no-float64" in rule_ids(got)
    finally:
        tmp.unlink()


def test_clean_pass_on_real_src():
    got = lint_paths([str(REPO / "src")])
    assert got == [], "\n".join(f.format() for f in got)


# ---------------------------------------------------------------------------
# Flow invariants: each seeded trace violation is caught
# ---------------------------------------------------------------------------

def test_flow_int8_accum():
    def bad(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))

    spec = TraceSpec("fix_int8_accum", bad,
                     (_sds((8, 16), jnp.int8), _sds((16, 8), jnp.int8)), {})
    assert "int8-accum" in rule_ids(check_trace(spec))

    def good(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)

    spec = TraceSpec("fix_int8_accum_ok", good,
                     (_sds((8, 16), jnp.int8), _sds((16, 8), jnp.int8)), {})
    assert check_trace(spec) == []


def test_flow_scale_free_escape():
    def bad(q):
        return q.astype(jnp.float32)

    spec = TraceSpec("fix_escape", bad, (_sds((4, 4), jnp.int8),),
                     {0: "quant"})
    got = check_trace(spec)
    assert rule_ids(got) == ["scale-once"]
    assert "never applied" in got[0].message


def test_flow_double_scaling():
    def bad(q, s):
        return q.astype(jnp.float32) * s * s

    args = (_sds((4, 4), jnp.int8), _sds((4, 1), jnp.float32))
    spec = TraceSpec("fix_double", bad, args, {0: "quant", 1: "scale"})
    got = check_trace(spec)
    assert "scale-once" in rule_ids(got)
    assert any("double-scal" in f.message for f in got)

    def good(q, s):
        return q.astype(jnp.float32) * s

    spec = TraceSpec("fix_double_ok", good, args, {0: "quant", 1: "scale"})
    assert check_trace(spec) == []


def test_flow_scale_mismatch():
    def bad(q, s):
        dequantized = q.astype(jnp.float32) * s
        return dequantized + q.astype(jnp.float32)

    args = (_sds((4, 4), jnp.int8), _sds((4, 1), jnp.float32))
    spec = TraceSpec("fix_mismatch", bad, args, {0: "quant", 1: "scale"})
    assert "scale-mismatch" in rule_ids(check_trace(spec))


def test_flow_packed_int4_upcast():
    def bad(p):
        return p.astype(jnp.float32)

    spec = TraceSpec("fix_packed", bad, (_sds((8, 8), jnp.int8),),
                     {0: "packed"})
    assert "packed-int4-upcast" in rule_ids(check_trace(spec))

    def good(p, s):
        lo = jax.lax.shift_right_arithmetic(
            jax.lax.shift_left(p, jnp.int8(4)), jnp.int8(4))
        return lo.astype(jnp.float32) * s

    args = (_sds((8, 8), jnp.int8), _sds((8, 1), jnp.float32))
    spec = TraceSpec("fix_packed_ok", good, args, {0: "packed", 1: "scale"})
    assert check_trace(spec) == []


def test_flow_nonlinear_on_unscaled():
    def bad(q):
        return jnp.exp(q.astype(jnp.float32))

    spec = TraceSpec("fix_nonlinear", bad, (_sds((4, 4), jnp.int8),),
                     {0: "quant"})
    assert "nonlinear-on-unscaled" in rule_ids(check_trace(spec))


def test_flow_kernel_suite_clean():
    # the fast suite: ref oracles + jitted Pallas kernels for int8 GEMM,
    # w4a8 GEMM and paged-attention dequant (model-level traces run in CI
    # via `python -m repro.analysis src`)
    got = check_suite(default_specs(fast=True))
    assert got == [], "\n".join(f.format() for f in got)


# ---------------------------------------------------------------------------
# Satellite: benchmarks/bench_serving.py imports without side effects
# ---------------------------------------------------------------------------

def test_bench_serving_importable():
    bench_dir = str(REPO / "benchmarks")
    sys.path.insert(0, bench_dir)
    try:
        mod = importlib.import_module("bench_serving")
        assert callable(mod.main)
        # PYTHONPATH already resolves repro: the import must not have
        # prepended its own src path
        assert not any(p.endswith("benchmarks/../src") for p in sys.path)
    finally:
        sys.path.remove(bench_dir)
        sys.modules.pop("bench_serving", None)

"""End-to-end behaviour tests for the paper's system: the full
train -> calibrate -> PTQ -> quantized CoT serving path on one subject."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core.quant import INT8, W4A8_SMOOTH, calibrate, ptq
from repro.data import DataConfig, SyntheticLM, make_prompts
from repro.models import transformer
from repro.optim import adamw
from repro.serving import ServingEngine, cot
from repro.train import trainer


@pytest.fixture(scope="module")
def system():
    """Train a tiny openPangu-class model until it beats chance, then
    calibrate it (the paper's full pipeline precondition)."""
    cfg = reduced(get_arch("pangu-1b"), groups=2)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=48, seed=0))
    ocfg = adamw.OptConfig(lr=3e-3, warmup_steps=10, total_steps=120)
    state = trainer.init_state(jax.random.PRNGKey(0), cfg, ocfg)
    step = jax.jit(trainer.make_train_step(cfg, ocfg, remat=False))
    first = last = None
    for i in range(120):
        state, m = step(state, data.batch(i, 8))
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.3, (first, last)
    stats = calibrate.collect_stats(state.params,
                                    data.batches(5000, 4, 8), cfg)
    return cfg, state.params, data, stats


def test_full_ptq_serving_pipeline_int8(system):
    """The paper's deployment path end-to-end: INT8 PTQ preserves greedy
    generations almost exactly on a trained model."""
    cfg, params, data, stats = system
    pq = ptq.quantize_model(params, cfg, INT8, stats)
    prompts = make_prompts(DataConfig(vocab=cfg.vocab, seq_len=48), 4, 10)

    eng_fp = ServingEngine(params, cfg)
    eng_q = ServingEngine(pq, cfg, qcfg=INT8, impl="xla")
    out_fp = eng_fp.generate(prompts, max_new=12, mode="slow_think")
    out_q = eng_q.generate(prompts, max_new=12, mode="slow_think")

    # The chain picks successors uniformly among 4 branches, so greedy
    # argmax sits on near-ties: trajectories may diverge under quant noise
    # (paper Fig. 3 shows the same wording divergence) — the invariant is
    # that INT8 generations stay *task-valid*, not token-identical.
    succ = np.asarray(data.succ)

    def validity(outs):
        ok = tot = 0
        for p_, g in zip(prompts, outs.tokens):
            seq = list(p_) + list(g)
            for a, b in zip(seq[len(p_) - 1:-1], seq[len(p_):]):
                ok += int(b in succ[a]); tot += 1
        return ok / max(tot, 1)

    v_fp, v_q = validity(out_fp), validity(out_q)
    assert v_fp > 0.7, v_fp
    assert v_q >= v_fp - 0.05, (v_fp, v_q)


def test_full_pipeline_all_cot_modes_w4a8(system):
    """W4A8+SmoothQuant serves all three reasoning modes with mode
    semantics intact (budgets ordered, outputs in-vocab)."""
    cfg, params, data, stats = system
    pq = ptq.quantize_model(params, cfg, W4A8_SMOOTH, stats)
    eng = ServingEngine(pq, cfg, qcfg=W4A8_SMOOTH, impl="xla")
    prompts = make_prompts(DataConfig(vocab=cfg.vocab, seq_len=48), 3, 8)
    study = eng.cot_study(prompts, max_new=16)
    assert set(study) == set(cot.MODES)
    assert study["no_think"]["mean_len"] < study["slow_think"]["mean_len"]
    for mode in cot.MODES:
        for g in study[mode]["generations"]:
            assert all(0 <= t < cfg.vocab for t in g)


def test_quantized_model_keeps_task_skill(system):
    """INT8 PTQ must preserve the trained model's next-token skill
    (per-token top-1 accuracy on held-out data within 2% of FP16)."""
    cfg, params, data, stats = system
    pq = ptq.quantize_model(params, cfg, INT8, stats)
    batch = data.batch(7000, 8)
    lf, _ = transformer.forward_train(params, batch, cfg, remat=False)
    lq, _ = transformer.forward_train(pq, batch, cfg, qcfg=INT8,
                                      impl="xla", remat=False)
    # labels are drawn uniformly among `branching` successors, so exact
    # top-1 is capped at 1/branching; the learnable skill is predicting a
    # *valid* successor.
    succ = jnp.asarray(data.succ)
    def valid_rate(logits):
        pred = jnp.argmax(logits, -1)
        return float(jnp.mean(jnp.any(
            succ[batch["tokens"]] == pred[..., None], axis=-1)))
    acc_f, acc_q = valid_rate(lf), valid_rate(lq)
    assert acc_f > 0.6, acc_f               # the model actually learned
    assert acc_q >= acc_f - 0.02, (acc_f, acc_q)

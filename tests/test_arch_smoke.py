"""Per-architecture smoke tests: REDUCED config of each assigned family runs
one forward + one train-gradient step on CPU; output shapes + finiteness.

Full-size configs are exercised only via the AOT dry-run (launch/dryrun.py).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.models import transformer


def make_batch(cfg, key, b=2, s=16):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.frontend == "embeddings":
        batch["embeds"] = jax.random.normal(ks[0], (b, s, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (b, s), 0, cfg.vocab)
    if cfg.frontend == "tokens+image":
        batch["ctx"] = jax.random.normal(ks[1], (b, cfg.n_ctx_tokens,
                                                 cfg.d_model), jnp.float32)
    batch["labels"] = jax.random.randint(ks[2], (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    batch = make_batch(cfg, key)
    logits, aux = transformer.forward_train(params, batch, cfg, remat=False)
    b = 2; s = 16
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(key, cfg)
    batch = make_batch(cfg, key)

    def loss_fn(p):
        total, parts = transformer.lm_loss(p, batch, cfg, remat=True)
        return total

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss {loss}"
    # sane CE magnitude for random init: ~log(vocab)
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 3 * np.log(cfg.vocab) + 2
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
    nonzero = sum(float(jnp.abs(g).sum()) > 0 for g in flat)
    assert nonzero > len(flat) * 0.5, f"{arch}: too many dead grads"


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "mixtral_8x7b", "hymba_1_5b",
                                  "xlstm_350m", "llama32_vision_90b",
                                  "musicgen_medium"])
def test_prefill_decode_matches_forward(arch):
    """decode_step after prefill must reproduce full-forward logits at the
    next position — validates every cache type (dense KV, rolling SWA,
    mamba state, mLSTM/sLSTM state, cross-attn ctx cache)."""
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(2)
    params = transformer.init_params(key, cfg)
    b, s = 2, 12
    batch = make_batch(cfg, key, b=b, s=s)

    logits_full, _ = transformer.forward_train(params, batch, cfg,
                                               remat=False)
    if cfg.frontend == "embeddings":
        pre = {"embeds": batch["embeds"][:, :s - 1]}
        last = batch["embeds"][:, s - 1:s]
    else:
        pre = {"tokens": batch["tokens"][:, :s - 1]}
        last = batch["tokens"][:, s - 1]
        if "ctx" in batch:
            pre["ctx"] = batch["ctx"]
    logits_pre, caches = transformer.prefill(params, pre, cfg, max_len=s + 4)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, s - 2]),
                               rtol=2e-2, atol=2e-2)
    pos = jnp.full((b,), s - 1, jnp.int32)
    logits_dec, _ = transformer.decode_step(params, caches, last, pos, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, s - 1]),
                               rtol=2e-2, atol=2e-2)

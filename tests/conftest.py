"""Shared fixtures for the serving/engine test suite.

One tiny reduced model is initialized per session (`cfg_params`) instead of
per test file, and `make_engine` is the single engine factory the engine
tests build on. The `kv_bits` fixture parameterizes over every pool dtype —
bf16, int8, and packed int4 — so engine-level guarantees (chunked prefill,
prefix caching, preemption, speculative decode) are exercised under all
three without per-file copy-paste.
"""
import jax
import pytest

from repro.configs import get_arch, reduced
from repro.models import transformer
from repro.serving import ContinuousBatchingEngine

ALL_KV_BITS = (16, 8, 4)       # bf16 / int8 / packed-int4 pool dtypes
QUANT_KV_BITS = (8, 4)         # the quantized pools (k_s/v_s scale leaves)


@pytest.fixture(scope="session")
def cfg_params():
    """Reduced pangu_1b config + params, shared across the whole session
    (read-only — tests must not mutate either)."""
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(params=ALL_KV_BITS)
def kv_bits(request):
    """Every pool dtype: 16 (bf16), 8 (int8), 4 (packed int4)."""
    return request.param


def make_engine(params, cfg, *, page_size=8, max_batch=3, max_seq_len=64,
                **kw):
    """The continuous-batching engine with the tiny-test geometry defaults
    the engine tests share; any engine kwarg (kv_bits, n_pages,
    prefix_cache, spec_decode, ...) can be overridden."""
    return ContinuousBatchingEngine(params, cfg, page_size=page_size,
                                    max_batch=max_batch,
                                    max_seq_len=max_seq_len, **kw)


def pool_leaves(kv_bits):
    """The pool leaf names a dtype carries (quantized pools add scales)."""
    return ("k", "v", "k_s", "v_s") if kv_bits != 16 else ("k", "v")

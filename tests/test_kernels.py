"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus the xla fallback wrappers."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.quant import qtypes
from repro.kernels import ref, ops
from repro.kernels import int8_gemm, w4a8_gemm, quantize_act, hadamard


def rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# INT8 GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(32, 128, 128), (128, 256, 384),
                                   (64, 512, 128), (256, 128, 256)])
def test_int8_gemm_matches_ref(m, k, n):
    r = rng(m + k + n)
    x = r.integers(-127, 128, (m, k)).astype(np.int8)
    w = r.integers(-127, 128, (k, n)).astype(np.int8)
    xs = r.uniform(0.001, 0.1, (m, 1)).astype(np.float32)
    ws = r.uniform(0.001, 0.1, (1, n)).astype(np.float32)
    got = int8_gemm.int8_matmul(x, w, xs, ws, bm=32, bn=128, bk=128,
                                interpret=True)
    want = ref.int8_matmul_ref(x, w, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int8_gemm_out_dtypes(out_dtype):
    r = rng(7)
    x = r.integers(-127, 128, (64, 128)).astype(np.int8)
    w = r.integers(-127, 128, (128, 128)).astype(np.int8)
    xs = r.uniform(0.001, 0.1, (64, 1)).astype(np.float32)
    ws = r.uniform(0.001, 0.1, (1, 128)).astype(np.float32)
    got = int8_gemm.int8_matmul(x, w, xs, ws, bm=32, bn=128, bk=128,
                                out_dtype=out_dtype, interpret=True)
    want = ref.int8_matmul_ref(x, w, xs, ws, out_dtype)
    assert got.dtype == out_dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-2)


def test_int8_gemm_wrapper_pads_and_batches():
    r = rng(3)
    x = r.integers(-127, 128, (2, 5, 7, 128)).astype(np.int8)  # odd M=70
    w = r.integers(-127, 128, (128, 256)).astype(np.int8)
    xs = r.uniform(0.001, 0.1, (2, 5, 7, 1)).astype(np.float32)
    ws = r.uniform(0.001, 0.1, (256,)).astype(np.float32)
    got = ops.int8_matmul(x, w, xs, ws, impl="pallas_interpret")
    want = ops.int8_matmul(x, w, xs, ws, impl="xla")
    assert got.shape == (2, 5, 7, 256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# W4A8 GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,g", [(32, 256, 128, 128), (64, 512, 256, 128),
                                     (16, 128, 128, 64), (128, 256, 384, 256)])
def test_w4a8_gemm_matches_ref(m, k, n, g):
    r = rng(m * 7 + k + n + g)
    x = r.integers(-127, 128, (m, k)).astype(np.int8)
    w4 = r.integers(-8, 8, (k, n)).astype(np.int8)
    wp = qtypes.pack_int4_halves(jnp.asarray(w4), g)
    xs = r.uniform(0.001, 0.1, (m, 1)).astype(np.float32)
    gs = r.uniform(0.001, 0.1, (k // g, n)).astype(np.float32)
    got = w4a8_gemm.w4a8_matmul(x, wp, xs, gs, group_size=g, bm=16, bn=128,
                                interpret=True)
    want = ref.w4a8_matmul_ref(x, wp, xs, gs, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_w4a8_pack_unpack_roundtrip_halves():
    r = rng(11)
    w4 = jnp.asarray(r.integers(-8, 8, (512, 64)).astype(np.int8))
    packed = qtypes.pack_int4_halves(w4, 128)
    assert packed.shape == (256, 64)
    back = qtypes.unpack_int4_halves(packed, 128)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w4))


def test_w4a8_wrapper_fallback_unaligned():
    r = rng(5)
    x = r.integers(-127, 128, (10, 256)).astype(np.int8)   # M=10 unaligned
    w4 = r.integers(-8, 8, (256, 96)).astype(np.int8)      # N=96 unaligned
    wp = qtypes.pack_int4_halves(jnp.asarray(w4), 128)
    xs = r.uniform(0.001, 0.1, (10, 1)).astype(np.float32)
    gs = r.uniform(0.001, 0.1, (2, 96)).astype(np.float32)
    got = ops.w4a8_matmul(x, wp, xs, gs, group_size=128, impl="pallas_interpret")
    want = ref.w4a8_matmul_ref(x, wp, xs, gs, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# Dynamic activation quant (+ fusions)
# ---------------------------------------------------------------------------

def _assert_int8_close(got, want, max_frac=0.01):
    """Quantized values may differ by 1 level at the +-127.5 clip boundary
    (paper Eq. 2 denominator 2^n - 1) due to XLA division reassociation."""
    diff = np.abs(np.asarray(got, np.int32) - np.asarray(want, np.int32))
    assert (diff <= 1).all(), f"max diff {diff.max()}"
    assert (diff != 0).mean() <= max_frac, f"{(diff != 0).mean():.4f} differ"


@pytest.mark.parametrize("m,k", [(8, 128), (64, 256), (17, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_act_matches_ref(m, k, dtype):
    r = rng(m + k)
    x = jnp.asarray(r.normal(0, 3, (m, k)), dtype)
    q, s = ops.quantize_act_dynamic(x, impl="pallas_interpret")
    qr, sr = ref.quantize_act_ref(x)
    _assert_int8_close(q, qr)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_quantize_act_fused_smooth():
    r = rng(21)
    x = jnp.asarray(r.normal(0, 1, (32, 256)), jnp.float32)
    sm = jnp.asarray(r.uniform(0.5, 2.0, (256,)), jnp.float32)
    q, s = ops.quantize_act_dynamic(x, sm, impl="pallas_interpret")
    qr, sr = ref.quantize_act_ref(x, sm)
    _assert_int8_close(q, qr)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_quantize_act_fused_hadamard():
    r = rng(22)
    x = jnp.asarray(r.normal(0, 1, (16, 256)), jnp.float32)
    q, s = ops.quantize_act_dynamic(x, hadamard_block=128,
                                    impl="pallas_interpret")
    qr, sr = ref.quantize_act_ref(x, hadamard_block=128)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    # rounding at +-0.5 boundaries can flip by 1 ulp of int; allow tiny diff
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert (diff <= 1).all() and (diff != 0).mean() < 0.01


def test_quantize_act_fused_rmsnorm():
    r = rng(23)
    x = jnp.asarray(r.normal(0, 1, (32, 128)), jnp.float32)
    g = jnp.asarray(r.uniform(0.5, 1.5, (128,)), jnp.float32)
    q, s = ops.quantize_act_dynamic(x, gamma=g, rmsnorm_eps=1e-6,
                                    impl="pallas_interpret")
    qr, sr = ref.fused_rmsnorm_quant_ref(x, g, 1e-6)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert (diff <= 1).all()


# ---------------------------------------------------------------------------
# Hadamard kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,b", [(8, 128, 128), (32, 512, 128), (16, 256, 64)])
def test_hadamard_kernel_matches_ref(m, k, b):
    r = rng(m + k + b)
    x = jnp.asarray(r.normal(0, 1, (m, k)), jnp.float32)
    got = hadamard.block_hadamard(x, block=b, interpret=True)
    want = ref.hadamard_ref(x, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_hadamard_orthogonal_roundtrip():
    r = rng(9)
    x = jnp.asarray(r.normal(0, 1, (8, 256)), jnp.float32)
    y = hadamard.block_hadamard(x, block=128, interpret=True)
    back = hadamard.block_hadamard(y, block=128, interpret=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=1e-4, atol=1e-5)

"""Unit tests for serving/sampling.py: the temperature / top-p paths and
the rejection-sampling acceptance rule speculative decoding builds on.

The distributional checks drive one jitted call with a large batch of
identical rows (speculative_accept draws independent uniforms per batch
element from a single key), so empirical frequencies converge at 1/sqrt(B)
and the tolerances stay loose enough for CI determinism across platforms.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.serving import sampling

VOCAB = 8


def _logits(rows, seed=0, scale=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(rows, VOCAB)) * scale, jnp.float32)


# -- basic samplers ----------------------------------------------------------

def test_greedy_is_argmax():
    lg = _logits(16)
    got = np.asarray(sampling.greedy(lg))
    np.testing.assert_array_equal(got, np.argmax(np.asarray(lg), axis=-1))


def test_temperature_low_temp_is_greedy():
    lg = _logits(32)
    got = np.asarray(sampling.temperature(lg, jax.random.PRNGKey(0),
                                          temp=1e-4))
    np.testing.assert_array_equal(got, np.argmax(np.asarray(lg), axis=-1))


def test_temperature_matches_softmax_frequencies():
    """Sampled frequencies track softmax(logits / temp) per temperature."""
    n = 20_000
    row = _logits(1, seed=3)
    lg = jnp.broadcast_to(row, (n, VOCAB))
    for temp in (0.5, 1.0, 2.0):
        want = np.asarray(jax.nn.softmax(row[0] / temp))
        got = np.asarray(sampling.temperature(lg, jax.random.PRNGKey(1),
                                              temp=temp))
        freq = np.bincount(got, minlength=VOCAB) / n
        np.testing.assert_allclose(freq, want, atol=0.015)


# -- nucleus filtering -------------------------------------------------------

def test_filter_top_p_keeps_smallest_covering_set():
    probs = np.array([0.5, 0.3, 0.15, 0.05], np.float32)
    lg = jnp.asarray(np.log(probs))[None, :]
    # p strictly between the cumulative masses (0.5 < 0.75 < 0.8) so f32
    # rounding of the cumsum can't flip the boundary token either way
    out = np.asarray(sampling.filter_top_p(lg, p=0.75))[0]
    # {0.5, 0.3} is the smallest covering set; the tail drops to NEG_INF
    assert np.isfinite(out[0]) and np.isfinite(out[1])
    assert out[2] <= sampling.NEG_INF and out[3] <= sampling.NEG_INF


def test_filter_top_p_identity_at_one():
    lg = _logits(4)
    np.testing.assert_array_equal(np.asarray(sampling.filter_top_p(lg, 1.0)),
                                  np.asarray(lg))


def test_filter_top_p_keeps_threshold_ties():
    probs = np.full(4, 0.25, np.float32)
    lg = jnp.asarray(np.log(probs))[None, :]
    out = np.asarray(sampling.filter_top_p(lg, p=0.5))[0]
    # every token ties at the nucleus boundary: all stay
    assert np.isfinite(out).all()


def test_top_p_never_samples_filtered_tokens():
    probs = np.array([0.6, 0.25, 0.1, 0.05], np.float32)
    lg = jnp.broadcast_to(jnp.asarray(np.log(probs)), (4096, 4))
    got = np.asarray(sampling.top_p(lg, jax.random.PRNGKey(2), p=0.7,
                                    temp=1.0))
    assert set(np.unique(got)) <= {0, 1}


# -- speculative acceptance --------------------------------------------------

def _window(b, c, seed=0):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, c, VOCAB)) * 2, jnp.float32)
    draft = jnp.asarray(rng.integers(0, VOCAB, size=(b, c)), jnp.int32)
    return logits, draft


def test_speculative_accept_greedy_matches_reference_walk():
    b, c = 64, 6
    logits, draft = _window(b, c, seed=5)
    n_new = jnp.asarray(np.random.default_rng(6).integers(0, c + 1, b),
                        jnp.int32)
    emit, acc = sampling.speculative_accept(
        logits, draft, n_new, jax.random.PRNGKey(0), mode="greedy")
    emit, acc = np.asarray(emit), np.asarray(acc)
    g = np.argmax(np.asarray(logits), axis=-1)
    d = np.asarray(draft)
    for i in range(b):
        a = 0
        while a + 1 < int(n_new[i]) and g[i, a] == d[i, a + 1]:
            a += 1
        assert acc[i] == a
        # accepted drafts echo, then the bonus token is the argmax after
        # the last accepted position
        for j in range(a):
            assert emit[i, j] == d[i, j + 1]
        if n_new[i] > 0:
            assert emit[i, a] == g[i, a]


def test_speculative_accept_idle_and_undrafted_lanes():
    b, c = 8, 5
    logits, draft = _window(b, c, seed=7)
    n_new = jnp.asarray([0, 1] * 4, jnp.int32)
    emit, acc = sampling.speculative_accept(
        logits, draft, n_new, jax.random.PRNGKey(0), mode="greedy")
    assert (np.asarray(acc) == 0).all()          # nothing to accept
    g = np.argmax(np.asarray(logits), axis=-1)
    # n_new == 1 lanes reduce to a vanilla decode step on position 0
    np.testing.assert_array_equal(np.asarray(emit)[1::2, 0], g[1::2, 0])


def test_speculative_accept_rate_is_draft_probability():
    """A deterministic proposal d is accepted with probability p(d)."""
    n = 40_000
    row = _logits(1, seed=11)[0]
    p = np.asarray(jax.nn.softmax(row))
    d = int(np.argsort(p)[-2])                  # a likely-but-not-top token
    logits = jnp.broadcast_to(row, (n, 2, VOCAB))
    draft = jnp.full((n, 2), d, jnp.int32)
    n_new = jnp.full((n,), 2, jnp.int32)
    _, acc = sampling.speculative_accept(
        logits, draft, n_new, jax.random.PRNGKey(3), mode="temperature",
        temp=1.0)
    assert abs(float(np.mean(np.asarray(acc))) - p[d]) < 0.01


def test_speculative_accept_preserves_target_distribution():
    """The first emitted token is distributed as softmax(logits / temp)
    regardless of what the drafter proposed (the lossless-ness guarantee
    of rejection sampling: accept + residual-resample == target)."""
    n = 60_000
    row = _logits(1, seed=13)[0]
    for d in (int(np.argmax(np.asarray(row))), 0):
        logits = jnp.broadcast_to(row, (n, 2, VOCAB))
        draft = jnp.full((n, 2), d, jnp.int32)
        n_new = jnp.full((n,), 2, jnp.int32)
        emit, _ = sampling.speculative_accept(
            logits, draft, n_new, jax.random.PRNGKey(d + 1),
            mode="temperature", temp=0.9)
        freq = np.bincount(np.asarray(emit)[:, 0], minlength=VOCAB) / n
        want = np.asarray(jax.nn.softmax(row / 0.9))
        np.testing.assert_allclose(freq, want, atol=0.015)

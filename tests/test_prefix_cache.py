"""Refcounted prefix caching: allocator sharing, chained page hashes, LRU
eviction, scheduler admission hits, shared-page preemption, and engine-level
bit-exactness of cache hits vs recompute (bf16, int8, and packed-int4
pools)."""
import jax
import numpy as np
import pytest
from conftest import QUANT_KV_BITS, make_engine, pool_leaves

from repro.serving.kv_pool import SCRATCH_PAGE, PageAllocator
from repro.serving.prefix_cache import PrefixCache, page_hashes
from repro.serving.scheduler import PagedScheduler, Request


def mk_req(rid, prompt, budget=4):
    return Request(rid=rid, prompt=list(prompt), mode="slow_think",
                   budget=budget)


# ---------------------------------------------------------------------------
# allocator refcounts
# ---------------------------------------------------------------------------

def test_allocator_refcount_sharing():
    a = PageAllocator(6)
    got = a.alloc(2)
    a.incref(got[0])
    assert a.refcount(got[0]) == 2 and a.refcount(got[1]) == 1
    a.free(got)                               # one holder of each
    assert a.refcount(got[0]) == 1 and a.n_free == 4
    with pytest.raises(AssertionError, match="double free"):
        a.free(got[1:])                       # refcount already 0
    a.free(got[:1])                           # last holder
    assert a.n_free == 5 and a.n_live == 0
    with pytest.raises(AssertionError, match="incref"):
        a.incref(got[0])                      # can't share a freed page


def test_allocator_park_adopt_reclaim():
    a = PageAllocator(6)
    claimed = []
    a.reclaim_hook = lambda p: claimed.append(p) or p % 2 == 1
    got = a.alloc(5)
    a.free(got)
    parked = [p for p in got if p % 2 == 1]
    assert sorted(claimed) == sorted(got)
    assert a.n_parked == len(parked) and a.n_free == 5 - len(parked)
    a.adopt(parked[0])                        # cache hit on a cold page
    assert a.refcount(parked[0]) == 1 and a.n_parked == len(parked) - 1
    with pytest.raises(AssertionError, match="adopt"):
        a.adopt(parked[0])
    a.reclaim(parked[1])                      # cache eviction
    assert a.n_parked == len(parked) - 2
    a.free([parked[0]])
    # invariant across every transition
    assert a.n_free + a.n_live + a.n_parked == 5


# ---------------------------------------------------------------------------
# chained page hashes
# ---------------------------------------------------------------------------

def test_page_hashes_chain_position_and_content():
    toks = list(range(10, 30))
    hs = page_hashes(toks, 8)
    assert len(hs) == 2                       # 20 tokens -> 2 full pages
    # shared prefix -> shared hash prefix; a divergence poisons the chain
    other = toks[:8] + [99] + toks[9:]
    ho = page_hashes(other, 8)
    assert ho[0] == hs[0] and ho[1] != hs[1]
    # same page content at a different position hashes differently
    assert page_hashes(toks[8:], 8)[0] != hs[1]
    # partial trailing pages are never hashed
    assert page_hashes(toks[:7], 8) == []


# ---------------------------------------------------------------------------
# PrefixCache LRU
# ---------------------------------------------------------------------------

def test_prefix_cache_lru_eviction_order():
    a = PageAllocator(8)
    cache = PrefixCache(a)
    hs = page_hashes(list(range(12)), 4)      # 3 hashes
    pages = a.alloc(3)
    assert cache.insert(hs, pages) == 3
    a.free(pages)                             # all park, LRU order = pages
    assert cache.n_unreferenced == 3 and a.n_parked == 3
    cache.acquire(pages[:1])                  # page 0 adopted -> referenced
    assert cache.n_unreferenced == 2
    a.free(pages[:1])                         # re-parks at the MRU end
    assert cache.evict(1) == 1                # coldest first: pages[1]
    assert cache.n_cached == 2 and a.n_free == 5
    assert cache.lookup(hs) == pages[:1]      # gap at hs[1] ends the run
    assert cache.evict(5) == 2                # drains, never over-frees
    assert cache.n_cached == 0 and a.n_free == 7 and cache.n_evicted == 3


# ---------------------------------------------------------------------------
# scheduler admission
# ---------------------------------------------------------------------------

def _finish_prefill(s, slot):
    n = len(s.active[slot].prompt)
    s.prefill_progress[slot] = n
    s.lengths[slot] = n


def test_admission_maps_cached_prefix():
    s = PagedScheduler(n_slots=2, n_pages=12, page_size=4,
                       max_pages_per_seq=4, prefix_cache=True)
    prompt = list(range(100, 110))            # 2 full pages + 2 tail tokens
    s.submit(mk_req(0, prompt))
    [(slot, _)] = s.admit(max_prefill_pages=4)
    assert s.prefill_progress[slot] == 0      # cold: nothing cached yet
    shared = s.seq_pages[slot][:2]
    _finish_prefill(s, slot)
    s.complete(slot)                          # promotes the 2 full pages
    assert s.cache.n_cached == 2 and s.cache.n_unreferenced == 2

    s.submit(mk_req(1, prompt))
    [(slot2, _)] = s.admit(max_prefill_pages=4)
    assert s.seq_pages[slot2][:2] == shared   # mapped, not reallocated
    assert int(s.prefill_progress[slot2]) == 8 == int(s.lengths[slot2])
    assert list(s.page_table[slot2, :3]) == s.seq_pages[slot2]
    assert s.prefix_hit_tokens == 8
    assert s.prefix_prompt_tokens == 2 * len(prompt)
    assert s.alloc.refcount(shared[0]) == 1   # adopted out of the LRU
    assert s.cache.n_unreferenced == 0


def test_page_aligned_prompt_recomputes_last_page():
    """A fully-cached page-aligned prompt must still recompute >= 1 token,
    else the mixed step has no last-token logits to sample from."""
    s = PagedScheduler(n_slots=2, n_pages=12, page_size=4,
                       max_pages_per_seq=4, prefix_cache=True)
    prompt = list(range(200, 208))            # exactly 2 pages
    s.submit(mk_req(0, prompt))
    [(slot, _)] = s.admit(max_prefill_pages=4)
    _finish_prefill(s, slot)
    s.complete(slot)
    assert s.cache.n_cached == 2
    s.submit(mk_req(1, prompt))
    [(slot2, _)] = s.admit(max_prefill_pages=4)
    # only the first page hits; the whole last page is recomputed
    assert int(s.prefill_progress[slot2]) == 4
    assert len(s.seq_pages[slot2]) == 2


def test_preempting_shared_holder_only_drops_refcount():
    s = PagedScheduler(n_slots=3, n_pages=16, page_size=4,
                       max_pages_per_seq=4, prefix_cache=True)
    prompt = list(range(50, 60))
    s.submit(mk_req(0, prompt))
    [(slot, _)] = s.admit(max_prefill_pages=4)
    _finish_prefill(s, slot)
    s.complete(slot)
    s.submit(mk_req(1, prompt))
    s.submit(mk_req(2, prompt))
    admitted = s.admit(max_prefill_pages=4)
    (sa, _), (sb, _) = admitted
    shared = s.seq_pages[sa][:2]
    assert s.seq_pages[sb][:2] == shared
    assert all(s.alloc.refcount(p) == 2 for p in shared)
    tail_a = s.seq_pages[sa][2]
    s._preempt(sb)                            # newest-yields victim
    # survivor's mapping is untouched; shared pages lost one holder only
    assert all(s.alloc.refcount(p) == 1 for p in shared)
    assert s.alloc.refcount(tail_a) == 1
    assert s.seq_pages[sa][:2] == shared
    assert list(s.page_table[sa, :3]) == s.seq_pages[sa]
    assert (s.page_table[sb] == SCRATCH_PAGE).all()


def test_lru_eviction_precedes_preemption():
    """A dry free list drains the cache LRU before any active request is
    preempted — the second-chance free list."""
    s = PagedScheduler(n_slots=2, n_pages=4, page_size=4,
                       max_pages_per_seq=3, prefix_cache=True)
    s.submit(mk_req(0, list(range(30, 38))))  # 2 pages, both promotable
    [(slot, _)] = s.admit(max_prefill_pages=3)
    _finish_prefill(s, slot)
    s.complete(slot)
    assert s.cache.n_unreferenced == 2 and s.alloc.n_free == 1
    s.submit(mk_req(1, list(range(60, 72))))  # 3 pages, no hits
    [(slot2, _)] = s.admit(max_prefill_pages=3)
    assert len(s.seq_pages[slot2]) == 3       # evicted 2 cold pages to fit
    assert s.cache.n_evicted == 2 and s.cache.n_cached == 0
    assert s.n_evictions == 0                 # nobody was preempted


# ---------------------------------------------------------------------------
# engine: cache hits are bit-exact with recompute
# ---------------------------------------------------------------------------

def _shared_prompts(page=8):
    common = list(range(1, 4 * page + 1))     # 4 shared full pages
    return [common + [401, 402, 403],
            common + [404, 405, 406, 407, 408],
            common + list(range(409, 409 + page))]


def test_engine_cache_hits_bitexact(cfg_params, kv_bits):
    cfg, params = cfg_params
    prompts = _shared_prompts()
    want = make_engine(params, cfg, kv_bits=kv_bits).run(prompts, max_new=8)
    eng = make_engine(params, cfg, kv_bits=kv_bits, prefix_cache=True)
    cold = eng.run(prompts, max_new=8)
    warm = eng.run(prompts, max_new=8)
    assert cold.tokens == want.tokens         # cold pass: no hits, no drift
    assert warm.tokens == want.tokens         # warm pass: hits, bit-exact
    assert cold.prefix_hit_tokens == 0
    assert warm.prefix_hit_tokens >= 3 * 4 * 8    # every shared page hit
    assert eng.compile_counts() == {"prefill": 0, "mixed": 1, "decode": 1,
                                    "verify": 0}
    stats = eng.prefix_cache_stats()
    assert stats["hit_rate"] > 0.4 and stats["cached_pages"] > 0


@pytest.mark.parametrize("kv_bits", QUANT_KV_BITS)
def test_warm_hits_reuse_identical_quantized_pages(cfg_params, kv_bits):
    """The pages a warm request maps are the exact quantized codes + scales
    the cold request wrote — int8 bytes and packed int4 nibbles alike are
    never requantized or rewritten on a hit."""
    cfg, params = cfg_params
    prompts = _shared_prompts()
    eng = make_engine(params, cfg, kv_bits=kv_bits, prefix_cache=True)
    eng.run(prompts, max_new=8)
    cached = sorted(eng.sched.cache._by_hash.values())
    assert cached
    before = jax.device_get(eng.pools)
    warm = eng.run(prompts, max_new=8)
    after = jax.device_get(eng.pools)
    assert warm.prefix_hit_tokens > 0
    for blk in before:
        for name in pool_leaves(kv_bits):
            np.testing.assert_array_equal(
                before[blk][name][:, cached], after[blk][name][:, cached])


def test_mid_prefill_preemption_with_shared_pages(cfg_params, kv_bits):
    """Tight pool + shared prefixes: preempting holders of shared pages
    (refcount drops, no double-free) and evicting cold cached pages still
    reproduces the roomy cache-off engine token-for-token."""
    cfg, params = cfg_params
    prompts = _shared_prompts()
    roomy = make_engine(params, cfg, kv_bits=kv_bits)
    want = roomy.run(prompts, max_new=8)
    tight = make_engine(params, cfg, kv_bits=kv_bits, n_pages=13,
                        prefix_cache=True)
    got = tight.run(prompts, max_new=8)
    assert got.tokens == want.tokens
    assert got.evictions > 0                  # preemption actually happened
    # and a second pass over the survivor cache still matches
    assert tight.run(prompts, max_new=8).tokens == want.tokens

"""mLSTM form equivalence: chunkwise scan == single-chunk parallel ==
step-by-step recurrent decode (the correctness backbone of the xlstm arch)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.models import xlstm as xl


def setup(s=32):
    cfg = reduced(get_arch("xlstm_350m"))
    key = jax.random.PRNGKey(0)
    p = xl.init_mlstm(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_chunked_equals_single_chunk():
    cfg, p, x = setup(32)
    out_full, st_full = xl.mlstm_parallel(p, x, cfg)
    old = xl.MLSTM_CHUNK
    try:
        xl.MLSTM_CHUNK = 8       # force 4 chunks
        out_chunk, st_chunk = xl.mlstm_parallel(p, x, cfg)
    finally:
        xl.MLSTM_CHUNK = old
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_full),
                               rtol=2e-3, atol=2e-3)
    for k in ("c", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_chunk[k]),
                                   np.asarray(st_full[k]),
                                   rtol=2e-3, atol=2e-3)


def test_parallel_equals_recurrent_decode():
    cfg, p, x = setup(12)
    out_par, st_par = xl.mlstm_parallel(p, x, cfg)
    st = xl.init_mlstm_state(cfg, 2)
    outs = []
    for t in range(x.shape[1]):
        o, st = xl.mlstm_decode(p, x[:, t:t + 1], cfg, st)
        outs.append(o)
    out_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_rec), np.asarray(out_par),
                               rtol=5e-3, atol=5e-3)
    for k in ("c", "n", "m"):
        np.testing.assert_allclose(np.asarray(st[k]), np.asarray(st_par[k]),
                                   rtol=5e-3, atol=5e-3)


def test_state_continuation_across_calls():
    """prefill(x1) then prefill(x2, state) == prefill(x1++x2)."""
    cfg, p, x = setup(24)
    out_all, st_all = xl.mlstm_parallel(p, x, cfg)
    out1, st1 = xl.mlstm_parallel(p, x[:, :12], cfg)
    out2, st2 = xl.mlstm_parallel(p, x[:, 12:], cfg, state=st1)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out_all[:, 12:]),
                               rtol=2e-3, atol=2e-3)
    for k in ("c", "n", "m"):
        np.testing.assert_allclose(np.asarray(st2[k]), np.asarray(st_all[k]),
                                   rtol=2e-3, atol=2e-3)

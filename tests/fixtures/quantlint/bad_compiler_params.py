"""Seeded violations: pallas-compiler-params + raw-compiler-params.

Never imported — parsed by tests/test_analysis.py through the AST linter.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def missing_compiler_params(x):
    # violation: no compiler_params= at all
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def raw_compiler_params(x):
    # violation x2: compiler_params not built via the shim, and a direct
    # TPUCompilerParams construction outside repro/kernels/__init__.py
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
    )(x)

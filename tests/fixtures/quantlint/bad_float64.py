"""Seeded violations: no-float64 (attribute and string spellings).

Never imported — parsed by tests/test_analysis.py through the AST linter.
"""
import jax.numpy as jnp
import numpy as np


def attr_spelling(x):
    return x.astype(jnp.float64)


def string_spelling(x):
    return x.astype("float64")


def numpy_attr(x):
    return np.asarray(x, dtype=np.float64)

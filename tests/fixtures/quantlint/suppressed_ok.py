"""Suppression-comment fixture: every seeded violation below is silenced.

Never imported — parsed by tests/test_analysis.py through the AST linter.
"""
import jax.numpy as jnp


def line_suppressed(x):
    return jnp.clip(x, -128, 127)  # quantlint: disable=magic-quant-literal


def multi_suppressed(x):
    return x.astype(jnp.float64) * 127.0  # quantlint: disable=no-float64,magic-quant-literal

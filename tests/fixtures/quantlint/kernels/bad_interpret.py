"""Seeded violations: pallas-interpret (path-scoped to kernels/ trees).

Never imported — parsed by tests/test_analysis.py through the AST linter.
"""
import jax
from jax.experimental import pallas as pl

from repro.kernels import tpu_compiler_params


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1


def no_escape_hatch(x):
    # violation: pallas_call without interpret=
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=tpu_compiler_params(),
    )(x)


def hardcoded_escape_hatch(x):
    # violation: interpret= passed but not plumbed from a wrapper parameter
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=tpu_compiler_params(),
        interpret=False,
    )(x)


def good_wrapper(x, *, interpret: bool = False):
    # NOT a violation: interpret= reaches callers
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=tpu_compiler_params(),
        interpret=interpret,
    )(x)

"""Seeded violations: magic-quant-literal (and one no-float64).

Never imported — parsed by tests/test_analysis.py through the AST linter.
"""
import jax.numpy as jnp


def clip_with_magic_range(x):
    # violations: -128, 127 clip bounds spelled as literals
    return jnp.clip(jnp.round(x), -128, 127)


def int4_denominator(absmax):
    # violation: the int4 scale denominator 15 spelled as a literal
    return 2.0 * absmax / 15


def sneaky_double(x):
    # violation: float spelling of the same bound
    return x * 127.0


def wide_accumulate(x):
    # violation: float64 anywhere in the pipeline
    return x.astype(jnp.float64)


def mxu_tile_ok(x):
    # NOT a violation: positive bare 128 is the ubiquitous MXU tile size
    return x.reshape(-1, 128)

#!/usr/bin/env bash
# Tier-1 CI: the exact command the roadmap gates on.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

#!/usr/bin/env bash
# Tier-1 CI: the exact commands the roadmap gates on.
#   1. quantlint — AST rules + jaxpr dtype-flow invariants over src/ (blocking)
#   2. pytest    — the tier-1 test suite
#   3. serving bench (smoke) — KV bytes ratios (int8 <= 0.55, packed int4
#      <= 0.30 of bf16), chunked-prefill speedup, prefix-cache
#      warm-TTFT/hit-rate/decode-floor gates, speculative decoding gates
#      (friendly speedup + bit-exact greedy, adversarial regression bound),
#      int4 functional/bit-exactness gates, decode-latency and
#      compile-count gates, pallas==xla token parity; metrics land in
#      bench_smoke.json (uploaded as a CI artifact)
#   4. serving bench (smoke, --kv-bits 4) — the same engine-level legs run
#      entirely on packed-int4 pages; metrics land in bench_smoke_int4.json
#      (uploaded as a separate CI artifact)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
python -m repro.analysis src
python -m pytest -x -q "$@"
python benchmarks/bench_serving.py --smoke --json bench_smoke.json
python benchmarks/bench_serving.py --smoke --kv-bits 4 \
    --json bench_smoke_int4.json

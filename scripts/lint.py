#!/usr/bin/env python
"""Repo entry point for the quantlint checker (== python -m repro.analysis).

    python scripts/lint.py [paths...] [--no-flow] [--list-rules]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())

"""End-to-end serving driver (the paper's deployment scenario):

  1. train a small openPangu-class model on the synthetic stream,
  2. calibrate + post-training-quantize it to INT8 (W8A8),
  3. serve batched requests under all three CoT reasoning modes,
  4. report per-mode accuracy/length/repetition, FP16 vs INT8.

    PYTHONPATH=src python examples/serve_quantized.py [--steps 200]
"""
import argparse
import time

import jax

from repro.configs import get_arch, reduced
from repro.core.quant import INT8, calibrate, ptq
from repro.data import DataConfig, SyntheticLM, make_prompts
from repro.optim import adamw
from repro.serving import ServingEngine
from repro.train import trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--max-new", type=int, default=24)
args = ap.parse_args()

# -- 1. train ---------------------------------------------------------------
cfg = reduced(get_arch("pangu-1b"), groups=2)
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, seed=0))
ocfg = adamw.OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
state = trainer.init_state(jax.random.PRNGKey(0), cfg, ocfg)
step = jax.jit(trainer.make_train_step(cfg, ocfg, remat=False))
t0 = time.time()
for i in range(args.steps):
    state, m = step(state, data.batch(i, 16))
print(f"[1] trained {args.steps} steps in {time.time() - t0:.0f}s, "
      f"loss {float(m['loss']):.3f}")

# -- 2. calibrate + PTQ -------------------------------------------------------
stats = calibrate.collect_stats(state.params, data.batches(9000, 6, 16), cfg)
params_q = ptq.quantize_model(state.params, cfg, INT8, stats)
print(f"[2] PTQ int8 done ({len(stats)} calibrated sites)")

# -- 3+4. serve both precisions across CoT modes ------------------------------
prompts = make_prompts(DataConfig(vocab=cfg.vocab, seq_len=64),
                       args.requests, 12)
for name, (q, p) in {"fp16": (None, state.params),
                     "int8": (INT8, params_q)}.items():
    eng = ServingEngine(p, cfg, qcfg=q, impl="xla" if q else None)
    study = eng.cot_study(prompts, max_new=args.max_new)
    for mode, r in study.items():
        print(f"[{name}] {mode:11s} mean_len={r['mean_len']:5.1f} "
              f"repetition={r['repetition_rate']:.2f} "
              f"sample={r['generations'][0][:8]}")
print("OK — quantized CoT serving end-to-end")

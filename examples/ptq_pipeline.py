"""PTQ pipeline walk-through: calibrate once, quantize under all four of
the paper's configurations (INT8, W4A8, W4A8-SmoothQuant, W4A8-Hadamard),
and print a mini Table 2 (perplexity / top-1 agreement / KL).

    PYTHONPATH=src python examples/ptq_pipeline.py [--arch qwen3-0.6b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core.quant import PRESETS, calibrate, ptq
from repro.data import DataConfig, SyntheticLM
from repro.models import transformer

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="pangu-1b")
args = ap.parse_args()

cfg = reduced(get_arch(args.arch))
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=48, seed=3))
params = transformer.init_params(jax.random.PRNGKey(1), cfg)

stats = calibrate.collect_stats(params, data.batches(0, 4, 8), cfg)
print(f"calibrated {len(stats)} activation sites "
      f"(per-channel absmax, shapes like {next(iter(stats.values())).shape})")

test = data.batch(100, 8)
ref, _ = transformer.forward_train(params, test, cfg, remat=False)
logp_ref = jax.nn.log_softmax(ref, -1)
p_ref = jax.nn.softmax(ref, -1)

print(f"{'scheme':16s} {'top1':>7s} {'KL':>9s}")
for name in ("int8", "w4a8", "w4a8-smooth", "w4a8-hadamard"):
    qcfg = PRESETS[name]
    pq = ptq.quantize_model(params, cfg, qcfg, stats)
    lq, _ = transformer.forward_train(pq, test, cfg, qcfg=qcfg, impl="xla",
                                      remat=False)
    top1 = float(jnp.mean(jnp.argmax(ref, -1) == jnp.argmax(lq, -1)))
    kl = float(jnp.mean(jnp.sum(p_ref * (logp_ref
                                         - jax.nn.log_softmax(lq, -1)), -1)))
    print(f"{name:16s} {top1:7.3f} {kl:9.5f}")
print("expected: int8 near-lossless; w4a8 degraded; smooth/hadamard "
      "recover on outlier-heavy real models (see benchmarks/table2)")

"""Fault-tolerance demo: train, kill mid-run (simulated preemption),
resume from the atomic checkpoint with exact data skip-ahead, and verify
the loss trajectory is identical to an uninterrupted run.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, reduced
from repro.data import DataConfig, SyntheticLM
from repro.optim import adamw
from repro.train import trainer

cfg = reduced(get_arch("qwen3-0.6b"))
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=48, seed=1))
ocfg = adamw.OptConfig(lr=2e-3, warmup_steps=5, total_steps=30)
step = jax.jit(trainer.make_train_step(cfg, ocfg, remat=False))

# uninterrupted reference run
state = trainer.init_state(jax.random.PRNGKey(7), cfg, ocfg)
ref_losses = []
for i in range(20):
    state, m = step(state, data.batch(i, 4))
    ref_losses.append(float(m["loss"]))

# interrupted run: checkpoint at step 10, "crash", resume, continue
with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d)
    state = trainer.init_state(jax.random.PRNGKey(7), cfg, ocfg)
    for i in range(10):
        state, m = step(state, data.batch(i, 4))
    ck.save(10, state, blocking=True)
    print(f"checkpoint at step 10 (loss {float(m['loss']):.4f}); "
          f"simulating preemption + restart")

    del state  # the 'crash'
    state2 = trainer.init_state(jax.random.PRNGKey(999), cfg, ocfg)  # fresh
    state2 = ck.restore(state2)  # elastic restore (any mesh/sharding)
    losses2 = []
    for i in range(10, 20):      # deterministic skip-ahead data
        state2, m = step(state2, data.batch(i, 4))
        losses2.append(float(m["loss"]))

np.testing.assert_allclose(losses2, ref_losses[10:], rtol=1e-5)
print("resumed trajectory identical to uninterrupted run:")
for a, b in zip(ref_losses[10:], losses2):
    print(f"  ref={a:.5f} resumed={b:.5f}")
print("OK")

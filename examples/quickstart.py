"""Quickstart: build a model, quantize it W8A8, run both, compare.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core.quant import INT8, calibrate, ptq
from repro.models import transformer

# 1. An openPangu-class model (reduced to CPU size; full config also works).
cfg = reduced(get_arch("pangu-1b"))
params = transformer.init_params(jax.random.PRNGKey(0), cfg)

# 2. A couple of calibration batches (per-channel activation absmax).
batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 32),
                                         0, cfg.vocab)} for i in range(2)]
stats = calibrate.collect_stats(params, batches, cfg)

# 3. Post-training quantization is a pure pytree transformation.
params_int8 = ptq.quantize_model(params, cfg, INT8, stats)
n_int8 = sum(l.size for l in jax.tree.leaves(params_int8)
             if l.dtype == jnp.int8)
print(f"quantized: {n_int8 / 1e6:.1f}M int8 weights")

# 4. Same model code runs both precisions.
batch = batches[0]
logits_fp, _ = transformer.forward_train(params, batch, cfg, remat=False)
logits_q, _ = transformer.forward_train(params_int8, batch, cfg,
                                        qcfg=INT8, impl="xla", remat=False)
top1 = float(jnp.mean(jnp.argmax(logits_fp, -1) == jnp.argmax(logits_q, -1)))
print(f"FP vs INT8 top-1 agreement: {top1:.3f}")
assert top1 > 0.9
print("OK")

from repro.optim import adamw  # noqa

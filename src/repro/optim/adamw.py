"""AdamW (from scratch), cosine schedule, global-norm clipping, and int8
gradient compression with error feedback for cross-pod data parallelism.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quant.qtypes import qmax, qmin


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    mu: dict
    nu: dict
    step: jax.Array


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(mu=z, nu=jax.tree.map(jnp.copy, z),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state: OptState, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = schedule(cfg, step)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(mu, nu, step), {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# INT8 gradient compression with error feedback (cross-pod DP all-reduce)
# ---------------------------------------------------------------------------

def compress_grads(grads, err):
    """Quantize each leaf to int8 (per-leaf symmetric scale) after adding the
    carried error; returns (q_leaves, scales, new_err). psum the int8 in
    int32, decompress with `decompress_grads`. Error feedback keeps the
    compression unbiased over steps (1-bit/8-bit SGD literature)."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        s = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / qmax(8)
        q = jnp.clip(jnp.round(t / s), qmin(8), qmax(8)).astype(jnp.int8)
        return q, s, t - q.astype(jnp.float32) * s

    flat, tdef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(err) if err is not None else [0.0] * len(flat)
    qs, ss, es = zip(*(one(g, e) for g, e in zip(flat, eflat)))
    return (jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, ss),
            jax.tree.unflatten(tdef, es))


def decompress_grads(q, scales):
    return jax.tree.map(lambda g, s: g.astype(jnp.float32) * s, q, scales)


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

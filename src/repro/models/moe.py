"""Mixture-of-Experts FFN (Mixtral-style: top-2 of 8, softmax-renormalized).

Two dispatch implementations:
  * "dense"    — every token through every expert, gate-weighted sum.
                 O(E) overcompute; kept as the correctness oracle.
  * "dropping" — static-shape capacity dispatch (MaxText/MegaBlocks style):
                 argsort tokens by expert, keep the first C per expert
                 (C = T*k*cf/E), grouped expert GEMMs, weighted scatter-add
                 back. Compiles to fixed shapes; dropped tokens contribute 0
                 (residual passes them through).

Expert weights carry a leading E dim; the quantized path vmaps `qlinear`
over experts (per-expert scales — the granularity the paper prescribes for
per-channel weight quantization).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qlinear
from repro.models.layers import Taps


def _maybe_constrain(x, sharding):
    """with_sharding_constraint when every named dim divides; the MoE
    dispatch scatter buffers otherwise replicate per device under GSPMD
    (200+ GiB/device at mixtral-8x22b train_4k)."""
    if sharding is None:
        return x
    spec = sharding.spec
    mesh = sharding.mesh
    if len(spec) > x.ndim:
        return x
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n:
            return x
    return jax.lax.with_sharding_constraint(x, sharding)


def init_moe(key, cfg) -> dict:
    d, ff, m = cfg.d_model, cfg.d_ff, cfg.moe
    e = m.num_experts
    k1, k2, k3 = jax.random.split(key, 3)
    n_in = 2 * ff if cfg.act == "swiglu" else ff
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": {"w": jax.random.normal(k1, (d, e), jnp.float32) * 0.02},
        "w_in": {"w": jax.random.normal(k2, (e, d, n_in), jnp.float32) * scale},
        "w_out": {"w": jax.random.normal(k3, (e, ff, d), jnp.float32)
                  / jnp.sqrt(ff)},
    }


def _expert_ffn(p_in, p_out, x, act, qcfg, impl, constraint=None):
    """x: (E, C, d) through per-expert FFN -> ((E, C, d), hidden absmax (E, ff)).

    The hidden absmax is the calibration tap for w_out (recorded outside the
    vmap to keep the Taps accumulator trace-safe). `constraint` is the 2-D
    (tokens, features) dispatch sharding — it must be re-asserted on the
    expert *hidden* states or GSPMD all-gathers them to the full ff width
    (160 GiB/device at mixtral-8x22b train_4k).."""
    def one(pi, po, xe):
        h = qlinear.apply(pi, xe, qcfg, impl)
        h = _maybe_constrain(h, constraint)
        if act == "swiglu":
            g, u = jnp.split(h, 2, axis=-1)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        elif act == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
        h = _maybe_constrain(h, constraint)
        ham = jnp.max(jnp.abs(h.astype(jnp.float32)), axis=0)
        out = qlinear.apply(po, h, qcfg, impl)
        return _maybe_constrain(out, constraint), ham
    return jax.vmap(one)(p_in, p_out, x)


def _router(p, x, m):
    """x: (T, d) -> gates (T, k) f32, ids (T, k) int32, aux load-balance loss."""
    logits = (x.astype(jnp.float32) @ p["router"]["w"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # GShard aux loss: E * sum_e mean(prob_e) * mean(assign_e)
    e = probs.shape[-1]
    assign = jnp.zeros_like(probs).at[
        jnp.arange(ids.shape[0])[:, None], ids].set(1.0)
    aux = e * jnp.sum(jnp.mean(probs, 0) * jnp.mean(assign, 0))
    return gates, ids, aux


def moe_ffn(p: dict, x: jax.Array, cfg, qcfg=None, impl=None,
            taps: Optional[Taps] = None, tap_prefix: str = "",
            constraint=None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss). `constraint`: optional
    NamedSharding with a (tokens, features) spec for dispatch buffers."""
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    if taps is not None:
        taps.record(tap_prefix + "mlp_in", xt)
    gates, ids, aux = _router(p, xt, m)
    if m.impl == "dense":
        out = _dense_moe(p, xt, gates, ids, cfg, qcfg, impl, taps, tap_prefix)
    else:
        out = _dropping_moe(p, xt, gates, ids, cfg, qcfg, impl, taps,
                            tap_prefix, constraint)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _expand_expert(constraint):
    """(tokens, feat) constraint -> (E, capacity, feat) for the expert buf."""
    if constraint is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = constraint.spec
    return NamedSharding(constraint.mesh, P(None, spec[0], spec[1]))


def _expand_vec(constraint):
    """(tokens, feat) constraint -> (tokens,) for the dispatch index vectors."""
    if constraint is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(constraint.mesh, P(constraint.spec[0]))


def _dense_moe(p, xt, gates, ids, cfg, qcfg, impl, taps, tap_prefix):
    m = cfg.moe
    t = xt.shape[0]
    # (E, T, d): every expert sees every token (oracle; smoke-test sizes only)
    xe = jnp.broadcast_to(xt[None], (m.num_experts,) + xt.shape)
    he, ham = _expert_ffn(p["w_in"], p["w_out"], xe, cfg.act, qcfg, impl)
    if taps is not None:
        taps.record_absmax(tap_prefix + "mlp_out", ham)
    weight = jnp.zeros((t, m.num_experts), jnp.float32).at[
        jnp.arange(t)[:, None], ids].add(gates)
    return jnp.einsum("etd,te->td", he.astype(jnp.float32), weight)


def _dropping_moe(p, xt, gates, ids, cfg, qcfg, impl, taps, tap_prefix,
                  constraint=None):
    m = cfg.moe
    t, d = xt.shape
    e, k = m.num_experts, m.top_k
    cap = int(t * k * m.capacity_factor / e + 0.999)
    cap = max(8, min(t, -(-cap // 8) * 8))             # round up to 8, <= T

    flat_e = ids.reshape(-1)                           # (T*k,)
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_e, stable=True)
    se, sg, st = flat_e[order], flat_gate[order], flat_tok[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)   # overflow row dropped

    # Shard the (T*k,) dispatch vectors over the token axis: gathers indexed
    # by replicated index vectors replicate their (T*k, d) outputs.
    c1 = _expand_vec(constraint)
    se, sg, st = (_maybe_constrain(a, c1) for a in (se, sg, st))
    slot = _maybe_constrain(slot, c1)
    keep = _maybe_constrain(keep, c1)

    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(
        _maybe_constrain(xt[st], constraint))
    ebuf = _maybe_constrain(buf[:-1].reshape(e, cap, d),
                            _expand_expert(constraint))
    he, ham = _expert_ffn(p["w_in"], p["w_out"], ebuf, cfg.act, qcfg, impl,
                          constraint)
    if taps is not None:
        taps.record_absmax(tap_prefix + "mlp_out", ham)
    he = _maybe_constrain(he.reshape(e * cap, d), constraint)
    contrib = he[jnp.minimum(slot, e * cap - 1)] * (sg * keep)[:, None]
    contrib = _maybe_constrain(contrib, constraint)
    out = jnp.zeros((t, d), jnp.float32).at[st].add(contrib.astype(jnp.float32))
    return _maybe_constrain(out, constraint)

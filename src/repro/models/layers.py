"""Shared model layers: norms, RoPE, MLP variants — all quantization-aware.

Every GEMM goes through `qlinear.apply`, so a PTQ'd parameter tree runs the
int8/int4 kernels with zero model-code changes. Activation statistics for
calibration are captured through the `Taps` accumulator threaded through the
forward pass (absmax per input channel — what SmoothQuant needs).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qlinear
from repro.core.quant.qtypes import QuantConfig


class Taps:
    """Per-channel absmax accumulator for calibration (traceable)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.data = {}

    def record(self, name: str, x: jax.Array) -> None:
        if not self.enabled:
            return
        red = tuple(range(x.ndim - 1))
        self.record_absmax(name, jnp.max(jnp.abs(x.astype(jnp.float32)),
                                         axis=red))

    def record_absmax(self, name: str, am: jax.Array) -> None:
        """am: (..., K) already-reduced absmax; leading dims are max-merged."""
        if not self.enabled:
            return
        if am.ndim > 1:
            am = jnp.max(am, axis=tuple(range(am.ndim - 1)))
        prev = self.data.get(name)
        self.data[name] = am if prev is None else jnp.maximum(prev, am)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * gamma.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, D/2)
    if ang.ndim == 2:                                 # (S, D/2) -> broadcast B
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]                 # (B, S, 1, D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, act: str) -> dict:
    k1, k2 = jax.random.split(key)
    if act == "swiglu":
        # fused [gate | up] halves in one GEMM
        return {"w_in": qlinear.init_linear(k1, d, 2 * ff),
                "w_out": qlinear.init_linear(k2, ff, d)}
    return {"w_in": qlinear.init_linear(k1, d, ff),
            "w_out": qlinear.init_linear(k2, ff, d)}


def mlp(p: dict, x: jax.Array, act: str,
        qcfg: Optional[QuantConfig] = None, impl: Optional[str] = None,
        taps: Optional[Taps] = None, tap_prefix: str = "") -> jax.Array:
    if taps is not None:
        taps.record(tap_prefix + "mlp_in", x)
    h = qlinear.apply(p["w_in"], x, qcfg, impl)
    if act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype) * up
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    if taps is not None:
        taps.record(tap_prefix + "mlp_out", h)
    return qlinear.apply(p["w_out"], h, qcfg, impl)

"""Attention: GQA / MQA, sliding-window, qk-norm, QKV-bias, cross-attention,
with dense / rolling-window / int8-quantized KV caches.

Projections are fused ([q|k|v] one GEMM) and quantization-aware. Scores and
softmax run in float32; grouped einsums avoid materializing repeated KV
heads. Rolling-window caches (Mixtral SWA) keep `window` slots and recover
absolute key positions arithmetically from the decode position, which is a
per-request vector (continuous batching).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qlinear
from repro.core.quant.qtypes import QuantConfig, paper_scale, qmax, qmin
from repro.models.layers import Taps, apply_rope, rms_norm

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 3)
    p = {}
    if cross:
        p["wq"] = qlinear.init_linear(ks[0], d, nq * hd, bias=cfg.qkv_bias)
        p["wkv"] = qlinear.init_linear(ks[1], d, 2 * nkv * hd, bias=cfg.qkv_bias)
    else:
        p["wqkv"] = qlinear.init_linear(ks[0], d, (nq + 2 * nkv) * hd,
                                        bias=cfg.qkv_bias)
    p["wo"] = qlinear.init_linear(ks[2], nq * hd, d)
    if cfg.qk_norm:
        p["qnorm"] = {"g": jnp.ones((hd,), jnp.float32)}
        p["knorm"] = {"g": jnp.ones((hd,), jnp.float32)}
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _qkv(p, x, cfg, positions, qcfg, impl, taps, tap_prefix, ctx=None,
         ctx_positions=None):
    """Project to q (B,S,Hq,hd), k/v (B,T,G,hd) with qk-norm + RoPE applied."""
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if ctx is None:
        if taps is not None:
            taps.record(tap_prefix + "attn_in", x)
        qkv = qlinear.apply(p["wqkv"], x, qcfg, impl)
        q, k, v = jnp.split(qkv, [nq * hd, (nq + nkv) * hd], axis=-1)
        k_positions = positions
    else:
        if taps is not None:
            taps.record(tap_prefix + "attn_in", x)
            taps.record(tap_prefix + "attn_ctx_in", ctx)
        q = qlinear.apply(p["wq"], x, qcfg, impl)
        kv = qlinear.apply(p["wkv"], ctx, qcfg, impl)
        k, v = jnp.split(kv, 2, axis=-1)
        k_positions = ctx_positions
    q = _split_heads(q, nq, hd)
    k = _split_heads(k, nkv, hd)
    v = _split_heads(v, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"]["g"], cfg.norm_eps)
        k = rms_norm(k, p["knorm"]["g"], cfg.norm_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
    if k_positions is not None:
        k = apply_rope(k, k_positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """q (B,S,Hq,hd); k,v (B,T,G,hd); mask broadcastable to (B,H,S,T).

    GQA KV heads are broadcast up to the full head count *at use*: the
    grouped (G, H/G) einsum form defeats GSPMD head-sharding whenever
    n_kv < the model-axis size (the 5-D reshape has no shardable head dim),
    which replicated 34 GB of scores in the 90B dry-run. The repeat is a
    broadcast XLA folds into the einsum; caches stay at n_kv heads."""
    b, s = q.shape[0], q.shape[1]
    nq, nkv = q.shape[2], k.shape[2]
    if nq != nkv and _GQA_GROUPED and (nq // nkv) % 16 == 0:
        # §Perf lever: grouped form keeps K/V at n_kv heads through the
        # score dot (no 16x K-read inflation for kv=2 archs like glm4);
        # only safe when the per-group head dim still shards (hper % 16).
        return _sdpa_grouped(q, k, v, mask)
    if nq != nkv:
        k = jnp.repeat(k, nq // nkv, axis=2)
        v = jnp.repeat(v, nq // nkv, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    # bf16 operands, f32 accumulation (MXU-native) — casting K/V to f32
    # up-front would double the gathered-KV footprint at 32k decode.
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if _SCORES_BF16:
        scores = scores.astype(jnp.bfloat16)
        scores = jnp.where(mask, scores, jnp.bfloat16(NEG_INF))
    else:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, -1).astype(q.dtype)


def _sdpa_grouped(q, k, v, mask):
    """GQA without KV repeat: (B,S,G,Hper,hd) x (B,T,G,hd)."""
    b, s = q.shape[0], q.shape[1]
    nkv = k.shape[2]
    hper = q.shape[2] // nkv
    qg = q.reshape(b, s, nkv, hper, q.shape[-1])
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.einsum("bsghd,btgd->bghst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                       scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bghst,btgd->bsghd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, -1).astype(q.dtype)


_GQA_GROUPED = bool(int(_os.environ.get("REPRO_GQA_GROUPED", "0")))     if "_os" in dir() else False

# Global score-element budget per attention chunk (f32 elements across the
# whole mesh); queries are processed in chunks beyond it (exact — each query
# row sees all its keys, no online-softmax needed). The HBM-conscious
# stand-in for a flash kernel at 32k prefill / 4k training. Bigger chunks
# cut the per-chunk K/V re-read traffic proportionally (a §Perf lever);
# override with REPRO_SCORE_BUDGET_LOG2.
import os as _os
_SCORE_BUDGET = 1 << int(_os.environ.get("REPRO_SCORE_BUDGET_LOG2", "31"))
_GQA_GROUPED = bool(int(_os.environ.get("REPRO_GQA_GROUPED", "0")))
# §Perf lever: store the masked scores/probs in bf16 (softmax still
# max-subtracted). Halves the dominant HBM traffic of XLA-lowered
# attention at 32k; a fused flash kernel removes it entirely.
_SCORES_BF16 = bool(int(_os.environ.get("REPRO_SCORES_BF16", "0")))
# Deployment flag: route causal attention through the Pallas flash kernel.
_USE_FLASH = bool(int(_os.environ.get("REPRO_FLASH", "0")))


def sdpa_causal(q, k, v, cfg, *, window: int = 0, lengths=None,
                t_offset: int = 0):
    """Query-chunked exact causal attention."""
    b, s = q.shape[0], q.shape[1]
    h = q.shape[2]
    t = k.shape[1]
    if _USE_FLASH and lengths is None and t_offset == 0 \
            and q.shape[-1] % 8 == 0:
        # Deployment path: the Pallas flash kernel (scores never touch
        # HBM). REPRO_FLASH=1 on TPU; interpret-mode execution elsewhere.
        from repro.kernels.flash_attn import flash_attention
        return flash_attention(
            q, k, v, causal=True, window=window,
            interpret=jax.default_backend() != "tpu").reshape(b, s, -1)
    if b * h * s * t <= _SCORE_BUDGET:
        mask = causal_mask(s, t_offset=t_offset, window=window,
                           lengths=lengths, t=t)
        return _sdpa(q, k, v, mask, cfg)
    qc = max(128, _SCORE_BUDGET // (b * h * t))
    while s % qc:
        qc //= 2
    nc = s // qc
    qs = q.reshape(b, nc, qc, *q.shape[2:]).swapaxes(0, 1)   # (nc,B,qc,H,hd)
    offsets = jnp.arange(nc) * qc + t_offset

    @jax.checkpoint
    def body(_, inp):
        qi, off = inp
        mask = causal_mask(qc, t_offset=off, window=window,
                           lengths=lengths, t=t)
        return (), _sdpa(qi, k, v, mask, cfg)

    _, outs = jax.lax.scan(body, (), (qs, offsets))
    return outs.swapaxes(0, 1).reshape(b, s, -1)


def causal_mask(s: int, t_offset: int = 0, window: int = 0,
                lengths: Optional[jax.Array] = None, t: Optional[int] = None):
    """(1|B, 1, S, T) boolean mask. t_offset: absolute position of query 0
    relative to key 0 (for chunked prefill)."""
    t = t if t is not None else s
    qpos = jnp.arange(s)[:, None] + t_offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    m = m[None, None]
    if lengths is not None:
        keyvalid = jnp.arange(t)[None, :] < lengths[:, None]   # (B, T)
        m = m & keyvalid[:, None, None, :]
    return m


# ---------------------------------------------------------------------------
# Train / prefill forward (no cache reads; returns k/v for cache build)
# ---------------------------------------------------------------------------

def attn_forward(p, x, cfg, positions, *, ctx=None, ctx_positions=None,
                 lengths=None, qcfg: Optional[QuantConfig] = None,
                 impl=None, taps: Optional[Taps] = None, tap_prefix=""):
    q, k, v = _qkv(p, x, cfg, positions, qcfg, impl, taps, tap_prefix,
                   ctx=ctx, ctx_positions=ctx_positions)
    s = x.shape[1]
    if ctx is None:
        out = sdpa_causal(q, k, v, cfg, window=cfg.sliding_window,
                          lengths=lengths)
    else:  # cross-attn: all context visible (context lengths assumed full)
        mask = jnp.ones((1, 1, s, ctx.shape[1]), bool)
        out = _sdpa(q, k, v, mask, cfg)
    if taps is not None:
        taps.record(tap_prefix + "attn_out", out)
    out = qlinear.apply(p["wo"], out, qcfg, impl)
    return out, (k, v)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, kv_bits: int = 16,
                  dtype=jnp.bfloat16) -> dict:
    """Dense or rolling-window cache. kv_bits == 8 stores int8 + scales
    (beyond-paper KV quantization)."""
    window = cfg.sliding_window
    size = min(window, max_len) if window else max_len
    nkv, hd = cfg.n_kv_heads, cfg.hd
    shape = (batch, size, nkv, hd)
    c = {}
    if kv_bits == 8:
        c["k"] = jnp.zeros(shape, jnp.int8)
        c["v"] = jnp.zeros(shape, jnp.int8)
        c["k_s"] = jnp.zeros((batch, size, nkv, 1), jnp.float32)
        c["v_s"] = jnp.zeros((batch, size, nkv, 1), jnp.float32)
    else:
        c["k"] = jnp.zeros(shape, dtype)
        c["v"] = jnp.zeros(shape, dtype)
    return c


def _kv_quant(x):
    """Per (token, head) symmetric int8. x: (..., hd)."""
    am = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    s = paper_scale(am, 8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s),
                 qmin(8), qmax(8)).astype(jnp.int8)
    return q, s


def _cache_read(c):
    if c["k"].dtype == jnp.int8:
        k = c["k"].astype(jnp.float32) * c["k_s"]
        v = c["v"].astype(jnp.float32) * c["v_s"]
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    return c["k"], c["v"]


def cache_write_prefill(c: dict, k, v) -> dict:
    """Write a full prefill (B, S, G, hd); keeps the last `size` positions
    for rolling caches. S <= max_len by construction."""
    size = c["k"].shape[1]
    s = k.shape[1]
    c = dict(c)
    if s >= size:
        k_keep, v_keep = k[:, s - size:], v[:, s - size:]
        slots = (jnp.arange(s - size, s) % size)
        if c["k"].dtype == jnp.int8:
            kq, ks = _kv_quant(k_keep)
            vq, vs = _kv_quant(v_keep)
            c["k"] = c["k"].at[:, slots].set(kq)
            c["v"] = c["v"].at[:, slots].set(vq)
            c["k_s"] = c["k_s"].at[:, slots].set(ks)
            c["v_s"] = c["v_s"].at[:, slots].set(vs)
        else:
            c["k"] = c["k"].at[:, slots].set(k_keep.astype(c["k"].dtype))
            c["v"] = c["v"].at[:, slots].set(v_keep.astype(c["v"].dtype))
        return c
    if c["k"].dtype == jnp.int8:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        c["k"] = jax.lax.dynamic_update_slice_in_dim(c["k"], kq, 0, 1)
        c["v"] = jax.lax.dynamic_update_slice_in_dim(c["v"], vq, 0, 1)
        c["k_s"] = jax.lax.dynamic_update_slice_in_dim(c["k_s"], ks, 0, 1)
        c["v_s"] = jax.lax.dynamic_update_slice_in_dim(c["v_s"], vs, 0, 1)
    else:
        c["k"] = jax.lax.dynamic_update_slice_in_dim(
            c["k"], k.astype(c["k"].dtype), 0, 1)
        c["v"] = jax.lax.dynamic_update_slice_in_dim(
            c["v"], v.astype(c["v"].dtype), 0, 1)
    return c


def _cache_write_step(c: dict, k, v, pos) -> dict:
    """Write one token per request. k,v: (B, 1, G, hd); pos: (B,) int32."""
    b = k.shape[0]
    slot = pos % c["k"].shape[1]
    idx = (jnp.arange(b), slot)
    c = dict(c)
    if c["k"].dtype == jnp.int8:
        kq, ks = _kv_quant(k[:, 0])
        vq, vs = _kv_quant(v[:, 0])
        c["k"] = c["k"].at[idx].set(kq)
        c["v"] = c["v"].at[idx].set(vq)
        c["k_s"] = c["k_s"].at[idx].set(ks)
        c["v_s"] = c["v_s"].at[idx].set(vs)
    else:
        c["k"] = c["k"].at[idx].set(k[:, 0].astype(c["k"].dtype))
        c["v"] = c["v"].at[idx].set(v[:, 0].astype(c["v"].dtype))
    return c


def decode_mask(c: dict, pos: jax.Array, window: int) -> jax.Array:
    """(B, 1, 1, size) validity of cache slots for queries at `pos` (B,).

    For slot s and current position P, the stored absolute key position is
    p = P - ((P - s) mod size); valid iff p >= 0, p <= P, and within window.
    """
    size = c["k"].shape[1]
    slots = jnp.arange(size)[None, :]
    pe = pos[:, None]
    kpos = pe - ((pe - slots) % size)
    valid = (kpos >= 0) & (kpos <= pe)
    if window:
        valid &= kpos > pe - window
    return valid[:, None, None, :]


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def attn_decode(p, x, cfg, cache: dict, pos: jax.Array, *,
                qcfg: Optional[QuantConfig] = None, impl=None):
    """x: (B, 1, d); pos: (B,) absolute position of this token.
    Returns (out (B,1,d), updated cache)."""
    q, k, v = _qkv(p, x, cfg, pos[:, None], qcfg, impl, None, "")
    cache = _cache_write_step(cache, k, v, pos)
    kc, vc = _cache_read(cache)
    mask = decode_mask(cache, pos, cfg.sliding_window)
    out = _sdpa(q, kc, vc, mask, cfg)
    out = qlinear.apply(p["wo"], out, qcfg, impl)
    return out, cache


def attn_decode_paged(p, x, cfg, pool: dict, page_table: jax.Array,
                      pos: jax.Array, *, qcfg: Optional[QuantConfig] = None,
                      impl=None, paged_impl: str = "xla"):
    """Decode step against the paged (optionally int8) KV pool.

    x: (B, 1, d); pos: (B,) absolute write position (== tokens already in
    cache); pool: one block's page pool (serving/kv_pool.py layout);
    page_table: (B, W) physical page ids. paged_impl selects the gather
    path: "xla" (jnp gather oracle) or "pallas"/"pallas_interpret" (the
    scalar-prefetch streaming kernel). Returns (out (B,1,d), pool)."""
    # Lazy imports: repro.serving imports this module at package init.
    from repro.kernels import paged_attn
    from repro.serving import kv_pool
    q, k, v = _qkv(p, x, cfg, pos[:, None], qcfg, impl, None, "")
    pool = kv_pool.write_token(pool, page_table, pos, k[:, 0], v[:, 0])
    kv_len = jnp.maximum(pos + 1, 1)      # dead slots attend scratch page 0
    ks, vs = pool.get("k_s"), pool.get("v_s")
    if paged_impl in ("pallas", "pallas_interpret"):
        out = paged_attn.paged_decode_attention(
            q[:, 0], pool["k"], pool["v"], ks, vs, page_table, kv_len,
            interpret=paged_impl == "pallas_interpret")
    else:
        out = paged_attn.paged_decode_attention_ref(
            q[:, 0], pool["k"], pool["v"], ks, vs, page_table, kv_len)
    out = out.reshape(x.shape[0], 1, -1).astype(x.dtype)
    out = qlinear.apply(p["wo"], out, qcfg, impl)
    return out, pool


def attn_prefill_chunk_paged(p, x, cfg, pool: dict, page_table: jax.Array,
                             window_rows: jax.Array, q_start: jax.Array,
                             n_new: jax.Array, *,
                             qcfg: Optional[QuantConfig] = None,
                             impl=None, paged_impl: str = "xla"):
    """Mixed chunked-prefill/decode attention step against the paged pool.

    x: (B, C, d) chunk hidden states at absolute positions q_start[b] + i;
    n_new: (B,) valid tokens this step (C = full prefill chunk, 1 = decode
    slot riding the mixed step, 0 = idle slot); window_rows: (B, Wc)
    physical pages covering the chunk's write window (kv_pool.write_chunk).

    The chunk's K/V is quantized and written *directly* into its pages
    (fused quantize-on-write — no dense cache), then the chunk queries
    attend causally over everything written so far, so intra-chunk
    attention sees the same (re-rounded) pages decode will. Returns
    (out (B, C, d), pool)."""
    from repro.kernels import paged_prefill
    from repro.serving import kv_pool
    b, c = x.shape[0], x.shape[1]
    positions = q_start[:, None] + jnp.arange(c)[None, :]
    q, k, v = _qkv(p, x, cfg, positions, qcfg, impl, None, "")
    pool = kv_pool.write_chunk(pool, k, v, window_rows, q_start, n_new)
    kv_len = jnp.maximum(q_start + n_new, 1)  # idle slots attend scratch
    ks, vs = pool.get("k_s"), pool.get("v_s")
    if paged_impl in ("pallas", "pallas_interpret"):
        out = paged_prefill.paged_prefill_attention(
            q, pool["k"], pool["v"], ks, vs, page_table, q_start, kv_len,
            interpret=paged_impl == "pallas_interpret")
    else:
        out = paged_prefill.paged_prefill_attention_ref(
            q, pool["k"], pool["v"], ks, vs, page_table, q_start, kv_len)
    out = out.reshape(b, c, -1).astype(x.dtype)
    out = qlinear.apply(p["wo"], out, qcfg, impl)
    return out, pool


def attn_verify_paged(p, x, cfg, pool: dict, page_table: jax.Array,
                      q_start: jax.Array, n_new: jax.Array, *,
                      qcfg: Optional[QuantConfig] = None,
                      impl=None, paged_impl: str = "xla"):
    """Speculative-verify attention step: score a k+1-token draft window
    in one pass against the paged pool, *without writing it*.

    Unlike `attn_prefill_chunk_paged` the window K/V never goes through
    the quantize-on-write path here — the raw projections are spliced
    over the gathered past keys inside the read
    (`paged_verify_attention`), so a fully rejected draft leaves the pool
    untouched and the engine commits only the accepted prefix afterwards
    (`kv_pool.write_chunk`, or `kv_pool.truncate` when a window was
    optimistically written). C = k+1 is not page-aligned. Returns
    (out (B, C, d), (k, v)) — the raw window projections the commit
    needs."""
    from repro.kernels import paged_prefill
    b, c = x.shape[0], x.shape[1]
    positions = q_start[:, None] + jnp.arange(c)[None, :]
    q, k, v = _qkv(p, x, cfg, positions, qcfg, impl, None, "")
    ks, vs = pool.get("k_s"), pool.get("v_s")
    out = paged_prefill.paged_verify_attention(
        q, pool["k"], pool["v"], ks, vs, page_table, q_start, n_new, k, v,
        interpret=paged_impl == "pallas_interpret")
    out = out.reshape(b, c, -1).astype(x.dtype)
    out = qlinear.apply(p["wo"], out, qcfg, impl)
    return out, (k, v)


def cross_decode(p, x, cfg, cache: dict, *, qcfg=None, impl=None):
    """Cross-attn at decode: context K/V precomputed at prefill."""
    nq, hd = cfg.n_heads, cfg.hd
    q = qlinear.apply(p["wq"], x, qcfg, impl)
    q = _split_heads(q, nq, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"]["g"], cfg.norm_eps)
    kc, vc = _cache_read(cache)
    mask = jnp.ones((1, 1, 1, kc.shape[1]), bool)
    out = _sdpa(q, kc, vc, mask, cfg)
    return qlinear.apply(p["wo"], out, qcfg, impl)


def init_cross_cache(cfg, batch: int, kv_bits: int = 16) -> dict:
    nkv, hd = cfg.n_kv_heads, cfg.hd
    t = cfg.n_ctx_tokens
    c = {}
    if kv_bits == 8:
        c["k"] = jnp.zeros((batch, t, nkv, hd), jnp.int8)
        c["v"] = jnp.zeros((batch, t, nkv, hd), jnp.int8)
        c["k_s"] = jnp.zeros((batch, t, nkv, 1), jnp.float32)
        c["v_s"] = jnp.zeros((batch, t, nkv, 1), jnp.float32)
    else:
        c["k"] = jnp.zeros((batch, t, nkv, hd), jnp.bfloat16)
        c["v"] = jnp.zeros((batch, t, nkv, hd), jnp.bfloat16)
    return c

"""Model zoo: generic pattern-driven decoder stack + block families."""

"""xLSTM blocks: mLSTM (matrix memory, exp gating) and sLSTM (scalar memory).

mLSTM trains/prefills in the parallel (quadratic, attention-like) form with
log-space gate stabilization and decodes through the O(1) recurrent matrix-
memory update — the two forms are numerically cross-checked in tests.
sLSTM is inherently sequential (recurrent gate connections) and runs under
`lax.scan` with exponential-gating stabilizer state.

Projections (up/qkv/down, fused gate input) are quantization-aware; the
recurrent R matrices and gate nonlinearity stay fp32 (state-fed — outside
SmoothQuant's calibration model; see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qlinear
from repro.models.layers import Taps, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg) -> dict:
    d = cfg.d_model
    dm = cfg.xlstm_proj * d
    nh = cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "w_up": qlinear.init_linear(ks[0], d, 2 * dm),     # [x | z-gate]
        "w_qkv": qlinear.init_linear(ks[1], dm, 3 * dm),
        "w_if": qlinear.init_linear(ks[2], dm, 2 * nh, bias=True),
        "w_down": qlinear.init_linear(ks[3], dm, d),
        "out_norm": {"g": jnp.ones((dm,), jnp.float32)},
    }


def _mlstm_qkvif(p, xm, cfg, qcfg, impl):
    nh = cfg.n_heads
    dm = xm.shape[-1]
    dh = dm // nh
    qkv = qlinear.apply(p["w_qkv"], xm, qcfg, impl)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shp = xm.shape[:-1] + (nh, dh)
    q, k, v = (t.reshape(shp).astype(jnp.float32) for t in (q, k, v))
    gates = qlinear.apply(p["w_if"], xm, qcfg, impl).astype(jnp.float32)
    log_i = gates[..., :nh]                         # i = exp(i~)
    log_f = jax.nn.log_sigmoid(gates[..., nh:])     # f = sigmoid(f~)
    return q, k, v, log_i, log_f


MLSTM_CHUNK = 1024     # quadratic-form window; beyond it, chunkwise scan


def _mlstm_chunk(state, q, k, v, log_i, log_f):
    """One chunkwise-parallel mLSTM step (the standard xLSTM chunked form).

    state: c (B,nh,dh,dh), n (B,nh,dh), m (B,nh); chunk tensors are
    (B,L,nh,dh) / (B,L,nh). Intra-chunk uses the stabilized quadratic form;
    the carried matrix memory contributes the inter-chunk term. With a zero
    state this reduces exactly to the full parallel form (tests cross-check
    against the recurrent step)."""
    c_prev, n_prev, m_prev = state["c"], state["n"], state["m"]
    bsz, l, nh, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    cum = jnp.cumsum(log_f, axis=1)                       # (B,L,nh)
    a_t = (log_i - cum).transpose(0, 2, 1)                # (B,nh,L)
    c_s = cum.transpose(0, 2, 1)                          # (B,nh,L)
    dmat = c_s[:, :, :, None] + a_t[:, :, None, :]        # (B,nh,L,L)
    tri = jnp.tril(jnp.ones((l, l), bool))
    dmat = jnp.where(tri, dmat, -jnp.inf)
    m_intra = jnp.max(dmat, axis=-1)                      # (B,nh,L)
    m_inter = m_prev[:, :, None] + c_s                    # (B,nh,L)
    m_j = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
    w_dec = jnp.exp(dmat - m_j[..., None])                # (B,nh,L,L)
    w_inter = jnp.exp(m_inter - m_j)                      # (B,nh,L)

    scores = jnp.einsum("bshd,bthd->bhst", q * scale, k)
    sw = scores * w_dec
    num = jnp.einsum("bhst,bthd->bshd", sw, v)            # (B,L,nh,dh)
    num_inter = jnp.einsum("bhkv,bshk->bshv", c_prev,
                           (q * scale) * w_inter.transpose(0, 2, 1)[..., None])
    num = num + num_inter
    den_intra = jnp.sum(sw, axis=-1)                      # (B,nh,L)
    den_inter = jnp.einsum("bhk,bshk->bhs", n_prev,
                           (q * scale) * w_inter.transpose(0, 2, 1)[..., None])
    den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_j))
    h = num / den.transpose(0, 2, 1)[..., None]           # (B,L,nh,dh)

    # end-of-chunk state
    cum_last = cum[:, -1]                                 # (B,nh)
    m_tail = jnp.max((log_i - cum) + cum_last[:, None], axis=1)  # (B,nh)
    m_new = jnp.maximum(m_prev + cum_last, m_tail)
    m_new = jnp.maximum(m_new, -1e30)
    w_tail = jnp.exp((log_i - cum) + cum_last[:, None]
                     - m_new[:, None]).transpose(0, 2, 1)  # (B,nh,L)
    decay = jnp.exp(m_prev + cum_last - m_new)
    c_new = decay[..., None, None] * c_prev + \
        jnp.einsum("bht,bthd,bthe->bhde", w_tail, k, v)
    n_new = decay[..., None] * n_prev + \
        jnp.einsum("bht,bthd->bhd", w_tail, k)
    return {"c": c_new, "n": n_new, "m": m_new}, h


def mlstm_parallel(p, x, cfg, *, qcfg=None, impl=None,
                   taps: Optional[Taps] = None, tap_prefix: str = "",
                   state=None):
    """x: (B, S, d) -> (out (B, S, d), final state (c, n, m)).

    Sequences longer than MLSTM_CHUNK run the chunkwise scan — the
    (B,nh,S,S) quadratic decay matrix at 32k prefill would otherwise
    materialize 34 GiB/device."""
    b, s, d = x.shape
    nh = cfg.n_heads
    if taps is not None:
        taps.record(tap_prefix + "up_in", x)
    up = qlinear.apply(p["w_up"], x, qcfg, impl)
    xm, z = jnp.split(up, 2, axis=-1)
    if taps is not None:
        taps.record(tap_prefix + "qkv_in", xm)
    q, k, v, log_i, log_f = _mlstm_qkvif(p, xm, cfg, qcfg, impl)
    dh = q.shape[-1]
    st = state if state is not None else init_mlstm_state(cfg, b)

    chunk = min(s, MLSTM_CHUNK)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    if nc == 1:
        st, h = _mlstm_chunk(st, q, k, v, log_i, log_f)
    else:
        split = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

        @jax.checkpoint
        def body(carry, inp):
            qi, ki, vi, li, lf = inp
            return _mlstm_chunk(carry, qi, ki, vi, li, lf)

        st, hs = jax.lax.scan(body, st, (split(q), split(k), split(v),
                                         split(log_i), split(log_f)))
        h = hs.swapaxes(0, 1).reshape(b, nc * chunk, nh, dh)
    h = h.reshape(b, s, -1)

    h = rms_norm(h, p["out_norm"]["g"], cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    if taps is not None:
        taps.record(tap_prefix + "down_in", h)
    out = qlinear.apply(p["w_down"], h.astype(x.dtype), qcfg, impl)
    return out, st


def mlstm_decode(p, x, cfg, state, *, qcfg=None, impl=None):
    """x: (B, 1, d); state: c (B,nh,dh,dh_v), n (B,nh,dh), m (B,nh)."""
    b = x.shape[0]
    up = qlinear.apply(p["w_up"], x, qcfg, impl)
    xm, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_qkvif(p, xm, cfg, qcfg, impl)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                   # (B,nh,dh)
    log_i, log_f = log_i[:, 0], log_f[:, 0]               # (B,nh)
    dh = q.shape[-1]

    m_new = jnp.maximum(log_f + state["m"], log_i)
    decay = jnp.exp(log_f + state["m"] - m_new)[..., None]
    inject = jnp.exp(log_i - m_new)[..., None]
    c = decay[..., None] * state["c"] + inject[..., None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = decay * state["n"] + inject * k
    qs = q / jnp.sqrt(jnp.float32(dh))
    num = jnp.einsum("bhde,bhd->bhe", c, qs)
    den = jnp.maximum(jnp.abs(jnp.sum(n * qs, axis=-1, keepdims=True)),
                      jnp.exp(-m_new)[..., None])
    h = (num / den).reshape(b, 1, -1)
    h = rms_norm(h, p["out_norm"]["g"], cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    out = qlinear.apply(p["w_down"], h.astype(x.dtype), qcfg, impl)
    return out, {"c": c, "n": n, "m": m_new}


def init_mlstm_state(cfg, batch: int) -> dict:
    dm = cfg.xlstm_proj * cfg.d_model
    nh = cfg.n_heads
    dh = dm // nh
    return {"c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg) -> dict:
    d = cfg.d_model
    nh = cfg.n_kv_heads or cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    return {
        "w_in": qlinear.init_linear(ks[0], d, 4 * d, bias=True),  # i,f,z,o
        "r": jax.random.normal(ks[1], (4, nh, dh, dh), jnp.float32)
        / jnp.sqrt(dh),
        "w_out": qlinear.init_linear(ks[2], d, d),
        "out_norm": {"g": jnp.ones((d,), jnp.float32)},
    }


def slstm_forward(p, x, cfg, *, qcfg=None, impl=None,
                  taps: Optional[Taps] = None, tap_prefix: str = "",
                  state=None):
    """Sequential scan over S. x: (B, S, d) -> (out, final state)."""
    b, s, d = x.shape
    nh = cfg.n_kv_heads or cfg.n_heads
    dh = d // nh
    if taps is not None:
        taps.record(tap_prefix + "in", x)
    zin = qlinear.apply(p["w_in"], x, qcfg, impl).astype(jnp.float32)
    st = state if state is not None else init_slstm_state(cfg, b)

    def step(carry, z_t):
        h, c, n, m = carry
        hh = h.reshape(b, nh, dh)
        rec = jnp.einsum("gude,bue->bgud", p["r"], hh).reshape(b, 4, d)
        it = z_t[:, 0 * d:1 * d] + rec[:, 0]
        ft = z_t[:, 1 * d:2 * d] + rec[:, 1]
        zt = z_t[:, 2 * d:3 * d] + rec[:, 2]
        ot = z_t[:, 3 * d:4 * d] + rec[:, 3]
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(zt)
        n_new = jnp.maximum(f_s * n + i_s, 1e-6)
        h_new = jax.nn.sigmoid(ot) * c_new / n_new
        return (h_new, c_new, n_new, m_new), h_new

    carry0 = (st["h"], st["c"], st["n"], st["m"])
    (hN, cN, nN, mN), hs = jax.lax.scan(step, carry0,
                                        zin.transpose(1, 0, 2))
    h_seq = hs.transpose(1, 0, 2)
    h_seq = rms_norm(h_seq, p["out_norm"]["g"], cfg.norm_eps)
    if taps is not None:
        taps.record(tap_prefix + "out", h_seq)
    out = qlinear.apply(p["w_out"], h_seq.astype(x.dtype), qcfg, impl)
    return out, {"h": hN, "c": cN, "n": nN, "m": mN}


def slstm_decode(p, x, cfg, state, *, qcfg=None, impl=None):
    out, st = slstm_forward(p, x, cfg, qcfg=qcfg, impl=impl, state=state)
    return out, st


def init_slstm_state(cfg, batch: int) -> dict:
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}

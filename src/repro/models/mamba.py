"""Mamba-style selective SSM branch (for the Hymba hybrid architecture).

Chunked selective scan: `lax.scan` over chunks of `ssm_chunk` tokens carries
the (B, d_inner, N) state; within a chunk an associative scan runs in
parallel. This bounds the materialized (token x d_inner x N) working set to
one chunk — the TPU-VMEM-conscious adaptation of the CUDA selective-scan
(DESIGN.md §2): recurrence stays in fast memory, HBM traffic is O(chunk).

Decode is the O(1) single-step recurrence on the carried state. Projections
(in/out/dt/BC) are quantization-aware; the scan itself runs fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qlinear
from repro.models.layers import Taps


def init_mamba(key, cfg) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    ks = jax.random.split(key, 6)
    s = 1.0 / jnp.sqrt(d)
    p = {
        "w_in": qlinear.init_linear(ks[0], d, 2 * di),        # [x | z]
        "w_bcdt": qlinear.init_linear(ks[1], di, 2 * n + 1),  # [B | C | dt]
        "w_out": qlinear.init_linear(ks[2], di, d),
        "conv": jax.random.normal(ks[3], (cfg.ssm_conv, di), jnp.float32) * 0.2,
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),                  # (di, N)
        "d_skip": jnp.ones((di,), jnp.float32),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),        # softplus ~ 0.01
    }
    return p


def _conv1d_causal(x, w):
    """Depthwise causal conv. x: (B, S, di); w: (K, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out


def _ssm_inputs(p, xz, cfg, qcfg, impl):
    """Shared by scan/step: gates + per-token (dt, B, C) from x branch."""
    di, n = cfg.d_inner, cfg.ssm_state
    x_raw, z = jnp.split(xz, 2, axis=-1)
    x = jax.nn.silu(_conv1d_causal(x_raw, p["conv"]).astype(jnp.float32))
    bcdt = qlinear.apply(p["w_bcdt"], x.astype(xz.dtype), qcfg, impl)
    bcdt = bcdt.astype(jnp.float32)
    b_t = bcdt[..., :n]                                   # (B,S,N)
    c_t = bcdt[..., n:2 * n]
    dt = jax.nn.softplus(bcdt[..., -1:] + p["dt_bias"])   # (B,S,di) broadcast
    a = -jnp.exp(p["a_log"])                              # (di, N)
    return x_raw, x, z, dt, a, b_t, c_t


def _scan_chunk(h0, x, dt, a, b_t, c_t):
    """One chunk in parallel. h0: (B, di, N); x,dt: (B,C,di); b,c: (B,C,N)."""
    decay = jnp.exp(dt[..., None] * a)                    # (B,C,di,N)
    drive = (dt * x)[..., None] * b_t[:, :, None, :]      # (B,C,di,N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    acc_a, acc_b = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    h = acc_a * h0[:, None] + acc_b                       # (B,C,di,N)
    y = jnp.einsum("bcdn,bcn->bcd", h, c_t)
    return h[:, -1], y


def mamba_forward(p, x_in, cfg, *, qcfg=None, impl=None,
                  taps: Optional[Taps] = None, tap_prefix: str = "",
                  state=None, constraint=None):
    """x_in: (B, S, d) -> (out (B, S, d), final state dict)."""
    b, s, _ = x_in.shape
    di, n = cfg.d_inner, cfg.ssm_state
    if taps is not None:
        taps.record(tap_prefix + "mamba_in", x_in)
    xz = qlinear.apply(p["w_in"], x_in, qcfg, impl)
    if constraint is not None:
        xz = jax.lax.with_sharding_constraint(xz, constraint)
    x_raw, x, z, dt, a, b_t, c_t = _ssm_inputs(p, xz, cfg, qcfg, impl)

    chunk = min(cfg.ssm_chunk, s)
    if s % chunk:
        chunk = 1 << (min(s, chunk).bit_length() - 1)
        while s % chunk:
            chunk //= 2
    nc = s // chunk
    h0 = (state["h"] if state is not None
          else jnp.zeros((b, di, n), jnp.float32))

    # checkpoint: the (B, chunk, di, N) decay/drive intermediates would
    # otherwise be stored per chunk for backward (86 GiB/dev at hymba
    # train_4k); recompute them instead.
    @jax.checkpoint
    def body(h, inputs):
        xc, dtc, bc, cc = inputs
        h1, y = _scan_chunk(h, xc, dtc, a, bc, cc)
        return h1, y

    split = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    hN, ys = jax.lax.scan(body, h0, (split(x), split(dt), split(b_t),
                                     split(c_t)))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + x * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    if taps is not None:
        taps.record(tap_prefix + "mamba_out", y)
    out = qlinear.apply(p["w_out"], y.astype(x_in.dtype), qcfg, impl)
    kc = cfg.ssm_conv - 1
    buf = jnp.pad(x_raw.astype(jnp.float32), ((0, 0), (kc, 0), (0, 0)))[:, -kc:]
    return out, {"h": hN, "conv_buf": buf}


def mamba_decode(p, x_in, cfg, state, *, qcfg=None, impl=None):
    """Single-token step. x_in: (B, 1, d); state: dict with h (B,di,N) and
    conv_buf (B, K-1, di) for the causal conv context."""
    b = x_in.shape[0]
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = qlinear.apply(p["w_in"], x_in, qcfg, impl)
    x_raw, z = jnp.split(xz, 2, axis=-1)
    # causal conv over buffered context + current token
    ctx = jnp.concatenate([state["conv_buf"],
                           x_raw.astype(jnp.float32)], axis=1)  # (B, K, di)
    x = jax.nn.silu(jnp.einsum("bkd,kd->bd", ctx.astype(jnp.float32),
                               p["conv"]))[:, None]
    bcdt = qlinear.apply(p["w_bcdt"], x.astype(xz.dtype), qcfg, impl)
    bcdt = bcdt.astype(jnp.float32)
    b_t, c_t = bcdt[..., :n], bcdt[..., n:2 * n]
    dt = jax.nn.softplus(bcdt[..., -1:] + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt[:, 0, :, None] * a)                     # (B,di,N)
    h = decay * state["h"] + (dt[:, 0] * x[:, 0])[..., None] * b_t[:, 0, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0])[:, None]
    y = y + x * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = qlinear.apply(p["w_out"], y.astype(x_in.dtype), qcfg, impl)
    new_state = {"h": h, "conv_buf": ctx[:, 1:]}
    return out, new_state


def init_mamba_state(cfg, batch: int) -> dict:
    return {"h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
            "conv_buf": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                                  jnp.float32)}

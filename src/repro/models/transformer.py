"""Generic decoder stack driven by `ArchConfig.pattern`.

The repeating block pattern is scanned over `n_groups` groups (params
stacked on a leading G axis), which keeps compile time flat in depth for
the 40-cell dry-run matrix. Block registry:

  self    — GQA/SWA attention + MLP            (dense, qwen*, glm4, nemotron)
  moe     — GQA/SWA attention + MoE FFN        (mixtral)
  cross   — cross-attention (image ctx) + MLP  (llama-3.2-vision)
  hybrid  — parallel attention ∥ mamba + MLP   (hymba)
  mlstm / slstm — xLSTM blocks                 (xlstm)

Three lowerable entry points per architecture:
  forward_train(...)  full-sequence logits (+taps/aux) — train_4k
  prefill(...)        full-sequence -> (last logits, caches) — prefill_32k
  decode_step(...)    one token against caches — decode_32k / long_500k

All GEMMs are quantization-aware: pass `qcfg` + PTQ'd params and the same
code runs the INT8/W4A8 kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qlinear
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import Taps, init_mlp, init_rms_norm, mlp, rms_norm


# ---------------------------------------------------------------------------
# Block registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockDef:
    init: Callable
    train: Callable        # (p, x, cfg, env) -> (x, cache_or_None, aux)
    decode: Callable       # (p, x, cfg, cache, env) -> (x, cache)
    init_cache: Callable   # (cfg, batch, max_len, kv_bits) -> cache
    quant_sites: Dict[str, list]


def _env_kw(env):
    return dict(qcfg=env.get("qcfg"), impl=env.get("impl"))


# -- self-attention + MLP ----------------------------------------------------

def _init_self(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms_norm(cfg.d_model),
            "attn": attn.init_attention(k1, cfg),
            "ln2": init_rms_norm(cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act)}


def _self_train(p, x, cfg, env):
    taps, pre = env.get("taps"), env.get("prefix", "")
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    a, kv = attn.attn_forward(p["attn"], h, cfg, env["positions"],
                              lengths=env.get("lengths"), taps=taps,
                              tap_prefix=pre, **_env_kw(env))
    x = x + a
    h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
    x = x + mlp(p["mlp"], h, cfg.act, env.get("qcfg"), env.get("impl"),
                taps, pre)
    cache = None
    if env.get("build_cache"):
        cache = attn.init_kv_cache(cfg, x.shape[0], env["max_len"],
                                   env.get("kv_bits", 16))
        cache = attn.cache_write_prefill(cache, *kv)
    return x, cache, 0.0


def _self_decode(p, x, cfg, cache, env):
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    a, cache = attn.attn_decode(p["attn"], h, cfg, cache, env["pos"],
                                **_env_kw(env))
    x = x + a
    h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
    x = x + mlp(p["mlp"], h, cfg.act, env.get("qcfg"), env.get("impl"))
    return x, cache


# -- MoE ----------------------------------------------------------------------

def _init_moe_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms_norm(cfg.d_model),
            "attn": attn.init_attention(k1, cfg),
            "ln2": init_rms_norm(cfg.d_model),
            "moe": moe_mod.init_moe(k2, cfg)}


def _moe_train(p, x, cfg, env):
    taps, pre = env.get("taps"), env.get("prefix", "")
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    a, kv = attn.attn_forward(p["attn"], h, cfg, env["positions"],
                              lengths=env.get("lengths"), taps=taps,
                              tap_prefix=pre, **_env_kw(env))
    x = x + a
    h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
    m, aux = moe_mod.moe_ffn(p["moe"], h, cfg, env.get("qcfg"),
                             env.get("impl"), taps, pre,
                             constraint=env.get("moe_sharding"))
    x = x + m
    cache = None
    if env.get("build_cache"):
        cache = attn.init_kv_cache(cfg, x.shape[0], env["max_len"],
                                   env.get("kv_bits", 16))
        cache = attn.cache_write_prefill(cache, *kv)
    return x, cache, aux


def _moe_decode(p, x, cfg, cache, env):
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    a, cache = attn.attn_decode(p["attn"], h, cfg, cache, env["pos"],
                                **_env_kw(env))
    x = x + a
    h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
    m, _ = moe_mod.moe_ffn(p["moe"], h, cfg, env.get("qcfg"), env.get("impl"),
                           constraint=env.get("moe_sharding"))
    return x + m, cache


# -- cross-attention ----------------------------------------------------------

def _init_cross(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms_norm(cfg.d_model),
            "attn": attn.init_attention(k1, cfg, cross=True),
            "ln2": init_rms_norm(cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act)}


def _cross_train(p, x, cfg, env):
    taps, pre = env.get("taps"), env.get("prefix", "")
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    a, kv = attn.attn_forward(p["attn"], h, cfg, None, ctx=env["ctx"],
                              taps=taps, tap_prefix=pre, **_env_kw(env))
    x = x + a
    h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
    x = x + mlp(p["mlp"], h, cfg.act, env.get("qcfg"), env.get("impl"),
                taps, pre)
    cache = None
    if env.get("build_cache"):
        cache = attn.init_cross_cache(cfg, x.shape[0], env.get("kv_bits", 16))
        cache = attn.cache_write_prefill(cache, *kv)
    return x, cache, 0.0


def _cross_decode(p, x, cfg, cache, env):
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    a = attn.cross_decode(p["attn"], h, cfg, cache, **_env_kw(env))
    x = x + a
    h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
    x = x + mlp(p["mlp"], h, cfg.act, env.get("qcfg"), env.get("impl"))
    return x, cache


# -- hybrid (attention ∥ mamba) ------------------------------------------------

def _init_hybrid(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_rms_norm(cfg.d_model),
            "attn": attn.init_attention(k1, cfg),
            "mamba": mb.init_mamba(k2, cfg),
            "norm_a": init_rms_norm(cfg.d_model),
            "norm_m": init_rms_norm(cfg.d_model),
            "ln2": init_rms_norm(cfg.d_model),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act)}


def _hybrid_train(p, x, cfg, env):
    taps, pre = env.get("taps"), env.get("prefix", "")
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    a, kv = attn.attn_forward(p["attn"], h, cfg, env["positions"],
                              lengths=env.get("lengths"), taps=taps,
                              tap_prefix=pre, **_env_kw(env))
    m, mstate = mb.mamba_forward(p["mamba"], h, cfg, taps=taps,
                                 tap_prefix=pre,
                                 constraint=env.get("mamba_sharding"),
                                 **_env_kw(env))
    fused = 0.5 * (rms_norm(a, p["norm_a"]["g"], cfg.norm_eps)
                   + rms_norm(m, p["norm_m"]["g"], cfg.norm_eps))
    x = x + fused
    h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
    x = x + mlp(p["mlp"], h, cfg.act, env.get("qcfg"), env.get("impl"),
                taps, pre)
    cache = None
    if env.get("build_cache"):
        kvc = attn.init_kv_cache(cfg, x.shape[0], env["max_len"],
                                 env.get("kv_bits", 16))
        cache = {"attn": attn.cache_write_prefill(kvc, *kv), "mamba": mstate}
    return x, cache, 0.0


def _hybrid_decode(p, x, cfg, cache, env):
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    a, kvc = attn.attn_decode(p["attn"], h, cfg, cache["attn"], env["pos"],
                              **_env_kw(env))
    m, mstate = mb.mamba_decode(p["mamba"], h, cfg, cache["mamba"],
                                **_env_kw(env))
    fused = 0.5 * (rms_norm(a, p["norm_a"]["g"], cfg.norm_eps)
                   + rms_norm(m, p["norm_m"]["g"], cfg.norm_eps))
    x = x + fused
    h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
    x = x + mlp(p["mlp"], h, cfg.act, env.get("qcfg"), env.get("impl"))
    return x, {"attn": kvc, "mamba": mstate}


# -- xLSTM ---------------------------------------------------------------------

def _init_mlstm_block(key, cfg):
    return {"ln1": init_rms_norm(cfg.d_model),
            "cell": xl.init_mlstm(key, cfg)}


def _mlstm_train(p, x, cfg, env):
    taps, pre = env.get("taps"), env.get("prefix", "")
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    y, state = xl.mlstm_parallel(p["cell"], h, cfg, taps=taps,
                                 tap_prefix=pre, **_env_kw(env))
    cache = state if env.get("build_cache") else None
    return x + y, cache, 0.0


def _mlstm_decode(p, x, cfg, cache, env):
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    y, state = xl.mlstm_decode(p["cell"], h, cfg, cache, **_env_kw(env))
    return x + y, state


def _init_slstm_block(key, cfg):
    return {"ln1": init_rms_norm(cfg.d_model),
            "cell": xl.init_slstm(key, cfg)}


def _slstm_train(p, x, cfg, env):
    taps, pre = env.get("taps"), env.get("prefix", "")
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    y, state = xl.slstm_forward(p["cell"], h, cfg, taps=taps,
                                tap_prefix=pre, **_env_kw(env))
    cache = state if env.get("build_cache") else None
    return x + y, cache, 0.0


def _slstm_decode(p, x, cfg, cache, env):
    h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
    y, state = xl.slstm_decode(p["cell"], h, cfg, cache, **_env_kw(env))
    return x + y, state


BLOCKS: Dict[str, BlockDef] = {
    "self": BlockDef(_init_self, _self_train, _self_decode,
                     lambda cfg, b, ml, kv: attn.init_kv_cache(cfg, b, ml, kv),
                     {"attn_in": ["attn/wqkv"], "attn_out": ["attn/wo"],
                      "mlp_in": ["mlp/w_in"], "mlp_out": ["mlp/w_out"]}),
    "moe": BlockDef(_init_moe_block, _moe_train, _moe_decode,
                    lambda cfg, b, ml, kv: attn.init_kv_cache(cfg, b, ml, kv),
                    {"attn_in": ["attn/wqkv"], "attn_out": ["attn/wo"],
                     "mlp_in": ["moe/w_in"], "mlp_out": ["moe/w_out"]}),
    "cross": BlockDef(_init_cross, _cross_train, _cross_decode,
                      lambda cfg, b, ml, kv: attn.init_cross_cache(cfg, b, kv),
                      {"attn_in": ["attn/wq"], "attn_ctx_in": ["attn/wkv"],
                       "attn_out": ["attn/wo"],
                       "mlp_in": ["mlp/w_in"], "mlp_out": ["mlp/w_out"]}),
    "hybrid": BlockDef(_init_hybrid, _hybrid_train, _hybrid_decode,
                       lambda cfg, b, ml, kv: {
                           "attn": attn.init_kv_cache(cfg, b, ml, kv),
                           "mamba": mb.init_mamba_state(cfg, b)},
                       {"attn_in": ["attn/wqkv"], "attn_out": ["attn/wo"],
                        "mamba_in": ["mamba/w_in"],
                        "mamba_out": ["mamba/w_out"],
                        "mlp_in": ["mlp/w_in"], "mlp_out": ["mlp/w_out"]}),
    "mlstm": BlockDef(_init_mlstm_block, _mlstm_train, _mlstm_decode,
                      lambda cfg, b, ml, kv: xl.init_mlstm_state(cfg, b),
                      {"up_in": ["cell/w_up"],
                       "qkv_in": ["cell/w_qkv", "cell/w_if"],
                       "down_in": ["cell/w_down"]}),
    "slstm": BlockDef(_init_slstm_block, _slstm_train, _slstm_decode,
                      lambda cfg, b, ml, kv: xl.init_slstm_state(cfg, b),
                      {"in": ["cell/w_in"], "out": ["cell/w_out"]}),
}


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def param_dtype():
    """Parameter storage dtype. REPRO_PARAM_DTYPE=bf16 selects mixed-
    precision training (bf16 params + f32 AdamW moments): halves FSDP
    weight-gather AND gradient all-reduce bytes — a §Perf lever."""
    import os
    return (jnp.bfloat16 if os.environ.get("REPRO_PARAM_DTYPE") == "bf16"
            else jnp.float32)


def init_params(key, cfg) -> dict:
    keys = jax.random.split(key, len(cfg.pattern) + 3)
    params: Dict[str, Any] = {}
    dt = param_dtype()
    if cfg.frontend != "embeddings":
        params["embed"] = {"w": (jax.random.normal(
            keys[-1], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt)}
    blocks = {}
    for i, btype in enumerate(cfg.pattern):
        gk = jax.random.split(keys[i], cfg.n_groups)
        blocks[str(i)] = jax.vmap(lambda k: BLOCKS[btype].init(k, cfg))(gk)
    params["blocks"] = blocks
    params["final_norm"] = init_rms_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = qlinear.init_linear(keys[-2], cfg.d_model,
                                                cfg.vocab)
    if dt != jnp.float32:
        params = jax.tree.map(
            lambda x: x.astype(dt) if x.dtype == jnp.float32 else x, params)
    return params


def _embed_inputs(params, batch, cfg, dtype):
    if cfg.frontend == "embeddings":
        return batch["embeds"].astype(dtype)
    return params["embed"]["w"].astype(dtype)[batch["tokens"]]


def padded_vocab(vocab: int) -> int:
    """LM-head width padded to a TPU/mesh-friendly multiple of 64 (hymba's
    32001-entry vocab otherwise forces replicated (B,S,V) f32 logits —
    30+ GiB/device at train_4k). Padded columns are masked to -1e9."""
    return -(-vocab // 64) * 64


def _lm_logits(params, x, cfg):
    if cfg.tie_embeddings:
        w = params["embed"]["w"].astype(x.dtype).T
    else:
        w = params["lm_head"]["w"].astype(x.dtype)
    vpad = padded_vocab(cfg.vocab)
    if vpad != cfg.vocab:
        w = jnp.pad(w, ((0, 0), (0, vpad - cfg.vocab)))
    logits = (x @ w).astype(jnp.float32)
    if vpad != cfg.vocab:
        mask = jnp.arange(vpad) < cfg.vocab
        logits = jnp.where(mask, logits, -1e9)
    return logits


def forward_train(params, batch, cfg, *, qcfg=None, impl=None,
                  collect_taps: bool = False, remat: bool = True,
                  dtype=jnp.bfloat16, shardings=None):
    """batch: {"tokens": (B,S)} or {"embeds": (B,S,d)}; optional "ctx"
    (B,T,d) image/frame context, "lengths" (B,).
    `shardings`: optional {"act": Sharding, "logits": Sharding} constraints
    (keeps the scan-carry activations and the (B,S,V) f32 logits sharded on
    big meshes — see launch/dryrun.py).
    Returns (logits (B,S,V) f32, aux dict with "taps", "moe_aux")."""
    shardings = shardings or {}
    x = _embed_inputs(params, batch, cfg, dtype)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    ctx = batch.get("ctx")
    if ctx is not None:
        ctx = ctx.astype(dtype)

    def body(x, gp):
        taps = Taps(collect_taps)
        aux = 0.0
        for i, btype in enumerate(cfg.pattern):
            env = {"positions": positions, "ctx": ctx,
                   "lengths": batch.get("lengths"), "qcfg": qcfg,
                   "impl": impl, "taps": taps, "prefix": f"{i}/",
                   "moe_sharding": shardings.get("moe"),
                   "mamba_sharding": shardings.get("act")}
            x, _, a = BLOCKS[btype].train(gp[str(i)], x, cfg, env)
            aux = aux + a
        if shardings.get("act") is not None:
            x = jax.lax.with_sharding_constraint(x, shardings["act"])
        return x, {"taps": taps.data, "moe_aux": aux}

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, ys = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"]["g"], cfg.norm_eps)
    logits = _lm_logits(params, x, cfg)
    if shardings.get("logits") is not None:
        logits = jax.lax.with_sharding_constraint(logits, shardings["logits"])
    aux = {"taps": ys["taps"], "moe_aux": jnp.sum(ys["moe_aux"])}
    return logits, aux


def prefill(params, batch, cfg, *, max_len: int, qcfg=None, impl=None,
            kv_bits: int = 16, dtype=jnp.bfloat16, shardings=None):
    """Run the prompt, build per-layer caches sized `max_len`.
    Returns (logits_last (B,V) f32, caches)."""
    shardings = shardings or {}
    x = _embed_inputs(params, batch, cfg, dtype)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    ctx = batch.get("ctx")
    if ctx is not None:
        ctx = ctx.astype(dtype)

    def body(x, gp):
        caches = {}
        for i, btype in enumerate(cfg.pattern):
            env = {"positions": positions, "ctx": ctx,
                   "lengths": batch.get("lengths"), "qcfg": qcfg,
                   "impl": impl, "build_cache": True, "max_len": max_len,
                   "kv_bits": kv_bits, "taps": None, "prefix": "",
                   "moe_sharding": shardings.get("moe"),
                   "mamba_sharding": shardings.get("act")}
            x, cache, _ = BLOCKS[btype].train(gp[str(i)], x, cfg, env)
            caches[str(i)] = cache
        if shardings.get("act") is not None:
            x = jax.lax.with_sharding_constraint(x, shardings["act"])
        return x, caches

    x, caches = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"]["g"], cfg.norm_eps)
    if "lengths" in batch and batch["lengths"] is not None:
        idx = jnp.maximum(batch["lengths"] - 1, 0)
        x_last = x[jnp.arange(x.shape[0]), idx]
    else:
        x_last = x[:, -1]
    logits = _lm_logits(params, x_last[:, None], cfg)[:, 0]
    return logits, caches


def decode_step(params, caches, token_or_embed, pos, cfg, *, qcfg=None,
                impl=None, dtype=jnp.bfloat16):
    """One decode step. token_or_embed: (B,) int32 tokens or (B,1,d) embeds;
    pos: (B,) absolute positions. Returns (logits (B,V) f32, caches)."""
    if cfg.frontend == "embeddings":
        x = token_or_embed.astype(dtype)
    else:
        x = params["embed"]["w"].astype(dtype)[token_or_embed][:, None, :]

    def body(x, scanned):
        gp, cache = scanned
        new = {}
        for i, btype in enumerate(cfg.pattern):
            env = {"pos": pos, "qcfg": qcfg, "impl": impl}
            x, c = BLOCKS[btype].decode(gp[str(i)], x, cfg, cache[str(i)], env)
            new[str(i)] = c
        return x, new

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    x = rms_norm(x, params["final_norm"]["g"], cfg.norm_eps)
    logits = _lm_logits(params, x, cfg)[:, 0]
    return logits, new_caches


# ---------------------------------------------------------------------------
# Paged decode (continuous-batching serving path)
# ---------------------------------------------------------------------------

PAGED_PATTERNS = ("self", "moe")


def supports_paged(cfg) -> bool:
    return (all(b in PAGED_PATTERNS for b in cfg.pattern)
            and cfg.sliding_window == 0 and cfg.frontend == "tokens")


def init_paged_pools(cfg, n_pages: int, page_size: int, kv_bits: int = 16,
                     dtype=jnp.bfloat16) -> dict:
    """Per-block page pools with the (G, ...) stacked structure the decode
    scan expects (mirrors init_caches). kv_bits selects the pool dtype —
    16 (dense `dtype`), 8 (int8 + per-(page, head) scales) or 4 (uint8
    nibble-packed int4, head_dim halved in storage); every jitted step
    below reads the pool dtype back off the leaves, so the same step
    functions serve all three."""
    from repro.serving import kv_pool   # serving imports models at init
    assert supports_paged(cfg), \
        f"paged decode supports patterns {PAGED_PATTERNS}, full attention"
    pools = {}
    for i, _ in enumerate(cfg.pattern):
        one = kv_pool.init_pool(cfg, n_pages, page_size, kv_bits, dtype)
        pools[str(i)] = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (cfg.n_groups,) + l.shape),
            one)
    return pools


def decode_step_paged(params, pools, page_table, tokens, pos, cfg, *,
                      qcfg=None, impl=None, paged_impl: str = "xla",
                      dtype=jnp.bfloat16):
    """One decode step against paged KV pools. tokens: (B,) int32; pos: (B,)
    absolute write positions (inactive slots: 0 with a scratch page-table
    row). Returns (logits (B,V) f32, pools)."""
    x = params["embed"]["w"].astype(dtype)[tokens][:, None, :]

    def body(x, scanned):
        gp, gpool = scanned
        new = {}
        for i, btype in enumerate(cfg.pattern):
            p = gp[str(i)]
            h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
            a, pool = attn.attn_decode_paged(
                p["attn"], h, cfg, gpool[str(i)], page_table, pos,
                qcfg=qcfg, impl=impl, paged_impl=paged_impl)
            x = x + a
            h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
            if btype == "moe":
                m, _ = moe_mod.moe_ffn(p["moe"], h, cfg, qcfg, impl)
                x = x + m
            else:
                x = x + mlp(p["mlp"], h, cfg.act, qcfg, impl)
            new[str(i)] = pool
        return x, new

    x, new_pools = jax.lax.scan(body, x, (params["blocks"], pools))
    x = rms_norm(x, params["final_norm"]["g"], cfg.norm_eps)
    logits = _lm_logits(params, x, cfg)[:, 0]
    return logits, new_pools


def prefill_chunk_paged(params, pools, page_table, window_rows, tokens,
                        q_start, n_new, cfg, *, qcfg=None, impl=None,
                        paged_impl: str = "xla", dtype=jnp.bfloat16):
    """One mixed chunked-prefill/decode step against paged KV pools — the
    single steady-state "mixed" compilation of the continuous-batching
    engine (C = chunk width is static; every step has the same shape, so
    decode latency stays flat while long prompts stream in chunks).

    tokens: (B, C) int32 — a prompt chunk for prefilling slots, the last
    sampled token in column 0 for decode slots, zeros for idle slots;
    q_start: (B,) absolute position of chunk token 0 (== tokens already in
    cache); n_new: (B,) valid tokens (C/partial = prefill chunk, 1 =
    decode, 0 = idle); window_rows: (B, Wc) write-window pages
    (kv_pool.write_chunk); page_table: (B, W) full table for reads.

    Each block quantizes the chunk's K/V straight into int8 or packed-int4
    pages (per-(page, head) scales) and attends causally over written pages
    plus the in-flight chunk. Returns (logits (B, V) f32 at each slot's
    last valid token, pools)."""
    c = tokens.shape[1]
    x = params["embed"]["w"].astype(dtype)[tokens]            # (B, C, d)

    def body(x, scanned):
        gp, gpool = scanned
        new = {}
        for i, btype in enumerate(cfg.pattern):
            p = gp[str(i)]
            h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
            a, pool = attn.attn_prefill_chunk_paged(
                p["attn"], h, cfg, gpool[str(i)], page_table, window_rows,
                q_start, n_new, qcfg=qcfg, impl=impl, paged_impl=paged_impl)
            x = x + a
            h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
            if btype == "moe":
                m, _ = moe_mod.moe_ffn(p["moe"], h, cfg, qcfg, impl)
                x = x + m
            else:
                x = x + mlp(p["mlp"], h, cfg.act, qcfg, impl)
            new[str(i)] = pool
        return x, new

    x, new_pools = jax.lax.scan(body, x, (params["blocks"], pools))
    x = rms_norm(x, params["final_norm"]["g"], cfg.norm_eps)
    last = jnp.clip(n_new - 1, 0, c - 1)
    x_last = x[jnp.arange(x.shape[0]), last]
    logits = _lm_logits(params, x_last[:, None], cfg)[:, 0]
    return logits, new_pools


def verify_step_paged(params, pools, page_table, tokens, q_start, n_new,
                      cfg, *, qcfg=None, impl=None, paged_impl: str = "xla",
                      dtype=jnp.bfloat16):
    """One speculative-verify step: score all C = k+1 positions of each
    sequence's draft window in a single forward (multi-query decode with
    causal masking over the window), *read-only* on the pools.

    tokens: (B, C) int32 — column 0 is the slot's last sampled-but-unwritten
    token, columns 1..n_new-1 are drafter proposals, the rest padding;
    q_start: (B,) tokens already committed to cache; n_new: (B,) window
    tokens (1 = plain decode lane with no draft, 0 = idle).

    The window is scored against the pages plus its own raw in-flight K/V
    (spliced inside the attention read — a rejected draft never touches
    the pool, so there is nothing to roll back), and the raw window
    projections are returned for the engine's commit: the accepted prefix
    goes through the fused quantize-on-write path (`kv_pool.write_chunk`,
    window pages sized by `kv_pool.verify_window_pages` — C unaligned,
    unlike the prefill chunk). Returns (logits (B, C, V) f32 at *every*
    window position, kv_win = {block: (k, v) (G, B, C, nkv, hd)})."""
    x = params["embed"]["w"].astype(dtype)[tokens]            # (B, C, d)

    def body(x, scanned):
        gp, gpool = scanned
        kvs = {}
        for i, btype in enumerate(cfg.pattern):
            p = gp[str(i)]
            h = rms_norm(x, p["ln1"]["g"], cfg.norm_eps)
            a, kv = attn.attn_verify_paged(
                p["attn"], h, cfg, gpool[str(i)], page_table,
                q_start, n_new, qcfg=qcfg, impl=impl, paged_impl=paged_impl)
            x = x + a
            h = rms_norm(x, p["ln2"]["g"], cfg.norm_eps)
            if btype == "moe":
                m, _ = moe_mod.moe_ffn(p["moe"], h, cfg, qcfg, impl)
                x = x + m
            else:
                x = x + mlp(p["mlp"], h, cfg.act, qcfg, impl)
            kvs[str(i)] = kv
        return x, kvs

    x, kv_win = jax.lax.scan(body, x, (params["blocks"], pools))
    x = rms_norm(x, params["final_norm"]["g"], cfg.norm_eps)
    logits = _lm_logits(params, x, cfg)
    return logits, kv_win


def init_caches(params, cfg, batch: int, max_len: int, kv_bits: int = 16):
    """Zero caches with the right per-group stacked structure."""
    caches = {}
    for i, btype in enumerate(cfg.pattern):
        one = BLOCKS[btype].init_cache(cfg, batch, max_len, kv_bits)
        caches[str(i)] = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (cfg.n_groups,) + l.shape),
            one)
    return caches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, batch, cfg, *, qcfg=None, impl=None, dtype=jnp.bfloat16,
            remat: bool = True, shardings=None):
    """Next-token cross-entropy (+ MoE aux + z-loss). batch needs "labels"."""
    logits, aux = forward_train(params, batch, cfg, qcfg=qcfg, impl=impl,
                                remat=remat, dtype=dtype,
                                shardings=shardings)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    zloss = 1e-4 * jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)
    moe_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    total = loss + zloss + moe_w * aux["moe_aux"] / max(cfg.n_layers, 1)
    return total, {"nll": loss, "zloss": zloss, "moe_aux": aux["moe_aux"]}

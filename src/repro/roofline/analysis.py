"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on the v5e
model in hw.py:

  compute    = HLO FLOPs / peak            (int8 cells: linear-GEMM FLOPs at
                                            the int8 peak, rest at bf16)
  memory     = HLO bytes accessed / HBM bw
  collective = collective bytes / ICI link bw

`cost_analysis()` numbers are per-device (the SPMD-partitioned module), so
terms divide by per-chip peaks directly. Collective bytes are parsed from
the partitioned HLO text: we record each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute with its operand bytes and
replica-group size, and report both the raw operand sum (the assignment's
definition) and a ring-adjusted estimate (bytes actually crossing links:
all-gather moves (n-1)x its operand shard, all-reduce ~2x(n-1)/n, etc.),
using the adjusted figure for the term.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class Collective:
    op: str
    operand_bytes: int
    group_size: int

    @property
    def link_bytes(self) -> int:
        """Ring-algorithm bytes crossing each chip's links."""
        n = max(self.group_size, 1)
        if n == 1:
            return 0
        if self.op.startswith("all-gather"):
            return self.operand_bytes * (n - 1)
        if self.op.startswith("all-reduce"):
            return int(2 * self.operand_bytes * (n - 1) / n)
        if self.op.startswith("reduce-scatter"):
            return int(self.operand_bytes * (n - 1) / n)
        if self.op.startswith("all-to-all"):
            return int(self.operand_bytes * (n - 1) / n)
        return self.operand_bytes  # collective-permute


def parse_collectives(hlo_text: str) -> List[Collective]:
    out = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"= [a-z0-9\[\],() ]*?(all-gather|all-reduce|"
                      r"reduce-scatter|all-to-all|collective-permute)"
                      r"(-start)?\(", stripped)
        if not m:
            continue
        op = m.group(1)
        # operand shapes: everything inside the call parens
        call = stripped[m.end():]
        operand_bytes = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(call))
        g = _GROUPS_RE.search(stripped)
        if g:
            group_size = g.group(1).count(",") + 1
        else:
            gi = _GROUPS_IOTA_RE.search(stripped)
            group_size = int(gi.group(2)) if gi else 1
        out.append(Collective(op, operand_bytes, group_size))
    return out


def collective_summary(colls: List[Collective]) -> Dict:
    by_op: Dict[str, Dict] = {}
    for c in colls:
        d = by_op.setdefault(c.op, {"count": 0, "operand_bytes": 0,
                                    "link_bytes": 0})
        d["count"] += 1
        d["operand_bytes"] += c.operand_bytes
        d["link_bytes"] += c.link_bytes
    return {
        "by_op": by_op,
        "total_operand_bytes": sum(c.operand_bytes for c in colls),
        "total_link_bytes": sum(c.link_bytes for c in colls),
        "count": len(colls),
    }


# ---------------------------------------------------------------------------
# Analytic model FLOPs (assignment formulas)
# ---------------------------------------------------------------------------

def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> Dict:
    """MODEL_FLOPS per the assignment: 6*N*D train (N=params; N_active for
    MoE), 2*N*D forward-only prefill, 2*N*B decode (one token). Also returns
    the analytic *linear-GEMM* forward FLOPs used to split the int8/bf16
    compute peaks."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        d_tokens = seq_len * global_batch
        total = 6 * n_active * d_tokens
        lin_fwd = 2 * n_active * d_tokens
    elif shape_kind == "prefill":
        d_tokens = seq_len * global_batch
        total = 2 * n_active * d_tokens
        lin_fwd = total
    else:  # decode: one token per request
        d_tokens = global_batch
        total = 2 * n_active * d_tokens
        lin_fwd = total
    # attention score/value FLOPs (forward), causal halved; SWA capped
    attn = 0
    n_attn_layers = sum(1 for b in cfg.pattern
                        if b in ("self", "moe", "cross", "hybrid"))
    n_attn_layers *= cfg.n_groups
    if n_attn_layers and cfg.n_heads:
        kv_len = seq_len if shape_kind != "decode" else seq_len
        if cfg.sliding_window:
            kv_len = min(kv_len, cfg.sliding_window)
        q_len = seq_len if shape_kind != "decode" else 1
        per_layer = 4 * global_batch * q_len * kv_len * cfg.n_heads * cfg.hd
        if shape_kind != "decode" and not cfg.sliding_window:
            per_layer //= 2  # causal
        attn = per_layer * n_attn_layers
        if shape_kind == "train":
            attn *= 3  # fwd + bwd
    return {"model_flops": total, "linear_fwd_flops": lin_fwd,
            "attn_flops": attn, "tokens": d_tokens}


# ---------------------------------------------------------------------------
# Terms
# ---------------------------------------------------------------------------

def roofline_terms(*, hlo_flops_per_dev: float, hlo_bytes_per_dev: float,
                   link_bytes_per_dev: float, n_chips: int,
                   int8_linear_flops_global: float = 0.0) -> Dict:
    """All inputs per-device except int8_linear_flops_global (analytic,
    divided by chips here)."""
    int8_per_dev = min(int8_linear_flops_global / n_chips, hlo_flops_per_dev)
    bf16_per_dev = hlo_flops_per_dev - int8_per_dev
    compute = bf16_per_dev / hw.PEAK_BF16 + int8_per_dev / hw.PEAK_INT8
    memory = hlo_bytes_per_dev / hw.HBM_BW
    collective = link_bytes_per_dev / hw.ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms.update({
        "dominant": dom,
        "step_s_lower_bound": bound,
        "roofline_fraction": compute / bound if bound > 0 else 0.0,
    })
    return terms

"""Loop-aware HLO cost walker.

XLA's `compiled.cost_analysis()` counts a `while` body **once**, but a
scanned 100-layer stack executes it `known_trip_count` times — the reported
FLOPs for the 90B train cell are ~12x under the 6*N*D model, and per-layer
weight all-gathers would be similarly undercounted in the collective term.

This walker parses `compiled.as_text()` (the SPMD-partitioned module, so
all shapes are per-device) and accumulates:

  * GEMM FLOPs from `dot` ops (2 x output elems x contracted size),
  * bytes accessed (operands + outputs of compute ops; fusions opaque,
    matching XLA's convention),
  * collectives (op kind, operand bytes, replica-group size),

multiplying everything by enclosing-loop trip counts taken from the
`backend_config={"known_trip_count":{"n":...}}` annotation on each `while`.
Validated against cost_analysis on loop-free modules (tests/test_roofline).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "opt-barrier"}

_COLLECTIVE_OPS = {"all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute"}


def _shape_bytes_elems(shape_str: str) -> Tuple[int, int]:
    """Total (bytes, elems) over every dtype[dims] literal in shape_str."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    rest: str            # args + attributes


@dataclasses.dataclass
class CollectiveUse:
    op: str
    operand_bytes: int
    group_size: int
    multiplier: int
    shape: str = ""

    @property
    def link_bytes(self) -> int:
        n = max(self.group_size, 1)
        if n == 1:
            return 0
        ob = self.operand_bytes
        if self.op == "all-gather":
            v = ob * (n - 1)
        elif self.op == "all-reduce":
            v = int(2 * ob * (n - 1) / n)
        elif self.op in ("reduce-scatter", "all-to-all"):
            v = int(ob * (n - 1) / n)
        else:
            v = ob
        return v * self.multiplier


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.shapes: Dict[str, str] = {}
        cur = None
        for line in text.splitlines():
            if line.endswith("{") and "->" in line and "(" in line:
                m = _COMP_HEADER_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, shape_str, opcode, rest = m.groups()
                inst = Instr(name, shape_str, opcode, rest)
                self.comps[cur].append(inst)
                self.shapes[name] = shape_str
        self.entry = self._find_entry(text)
        self._memo: Dict[str, dict] = {}

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HEADER_RE.match(line.strip())
                if m:
                    return m.group(1)
        return next(iter(self.comps))

    # -- per-op costs --------------------------------------------------------

    def _operand_names(self, inst: Instr) -> List[str]:
        args = inst.rest.split(")")[0]
        return re.findall(r"%([\w\.\-]+)", args)

    def _dot_flops(self, inst: Instr) -> int:
        _, out_elems = _shape_bytes_elems(inst.shape_str)
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.rest)
        ops = self._operand_names(inst)
        if not mc or not ops:
            return 2 * out_elems  # degenerate
        lhs_shape = self.shapes.get(ops[0], "")
        dims_m = _SHAPE_RE.search(lhs_shape)
        if not dims_m:
            return 2 * out_elems
        lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
        contracted = 1
        for i in mc.group(1).split(","):
            if i != "" and int(i) < len(lhs_dims):
                contracted *= lhs_dims[int(i)]
        return 2 * out_elems * contracted

    def _instr_bytes(self, inst: Instr) -> int:
        out_b, _ = _shape_bytes_elems(inst.shape_str)
        if inst.opcode in ("dynamic-slice", "gather"):
            # reads only the sliced/gathered window, not the full operand
            return 2 * out_b
        if inst.opcode in ("dynamic-update-slice", "scatter"):
            # read-modify-write of the update window; the big buffer is
            # aliased in place (XLA DUS fusion), not re-streamed
            ops = self._operand_names(inst)
            upd = 0
            if len(ops) >= 2:
                upd, _ = _shape_bytes_elems(self.shapes.get(ops[1], ""))
            return 2 * upd if upd else out_b
        op_b = 0
        for name in self._operand_names(inst):
            b, _ = _shape_bytes_elems(self.shapes.get(name, ""))
            op_b += b
        return out_b + op_b

    def _fusion_bytes(self, inst: Instr, called: str) -> int:
        """Boundary traffic of a fusion with slice-awareness: a parameter
        consumed only by dynamic-slice/gather inside contributes its slice
        size, not the whole buffer (scan xs slicing, cache reads); a DUS
        root writes its update window (in-place aliasing)."""
        comp = self.comps.get(called, [])
        params = {}                     # param instruction name -> index arg
        uses: Dict[str, List[Instr]] = {}
        for ins in comp:
            if ins.opcode == "parameter":
                params[ins.name] = ins
            for op in self._operand_names(ins):
                uses.setdefault(op, []).append(ins)
        total = 0
        for pname, pinst in params.items():
            pb, _ = _shape_bytes_elems(pinst.shape_str)
            consumers = uses.get(pname, [])
            if consumers and all(c.opcode in ("dynamic-slice", "gather")
                                 and self._operand_names(c)
                                 and self._operand_names(c)[0] == pname
                                 for c in consumers):
                total += sum(_shape_bytes_elems(c.shape_str)[0]
                             for c in consumers)
            elif consumers and all(
                    c.opcode == "dynamic-update-slice"
                    and self._operand_names(c)
                    and self._operand_names(c)[0] == pname
                    for c in consumers):
                for c in consumers:
                    ops = self._operand_names(c)
                    ub = (_shape_bytes_elems(self.shapes.get(ops[1], ""))[0]
                          if len(ops) >= 2 else 0)
                    total += ub
            else:
                total += pb
        root = comp[-1] if comp else None
        if root is not None and root.opcode == "dynamic-update-slice":
            ops = self._operand_names(root)
            ub = (_shape_bytes_elems(self.shapes.get(ops[1], ""))[0]
                  if len(ops) >= 2 else 0)
            total += ub or _shape_bytes_elems(inst.shape_str)[0]
        else:
            total += _shape_bytes_elems(inst.shape_str)[0]
        return total

    # -- recursive walk ------------------------------------------------------

    def cost(self, comp: Optional[str] = None) -> dict:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = {"flops": 0, "bytes": 0, "coll": [], "big": {}}
        for inst in self.comps.get(comp, []):
            if inst.opcode in _SKIP_OPS:
                continue
            if inst.opcode == "while":
                trip = 1
                m = _TRIP_RE.search(inst.rest)
                if m:
                    trip = int(m.group(1))
                body = _CALLS_RE.search(inst.rest)
                if body:
                    sub = self.cost(body.group(1))
                    total["flops"] += trip * sub["flops"]
                    total["bytes"] += trip * sub["bytes"]
                    total["coll"] += [
                        CollectiveUse(c.op, c.operand_bytes, c.group_size,
                                      c.multiplier * trip, c.shape)
                        for c in sub["coll"]]
                    for k2, v2 in sub["big"].items():
                        total["big"][k2] = total["big"].get(k2, 0) \
                            + v2 * trip
                continue
            if inst.opcode in ("fusion", "call", "async-start"):
                m = _CALLS_RE.search(inst.rest)
                if m and m.group(1) in self.comps:
                    sub = self.cost(m.group(1))
                    total["flops"] += sub["flops"]
                    total["coll"] += list(sub["coll"])
                    fb = self._fusion_bytes(inst, m.group(1))
                    total["bytes"] += fb
                    if fb > 1 << 22:
                        k2 = f"fusion {inst.shape_str[:48]}"
                        total["big"][k2] = total["big"].get(k2, 0) + fb
                else:
                    total["bytes"] += self._instr_bytes(inst)
                continue
            if inst.opcode == "conditional":
                # static branch cost: take the max branch
                branches = re.findall(r"%([\w\.\-]+)", inst.rest)
                subs = [self.cost(b) for b in branches if b in self.comps]
                if subs:
                    best = max(subs, key=lambda s: s["flops"] + s["bytes"])
                    total["flops"] += best["flops"]
                    total["bytes"] += best["bytes"]
                    total["coll"] += list(best["coll"])
                continue
            base = inst.opcode.replace("-start", "")
            if base in _COLLECTIVE_OPS:
                op_b = 0
                for name in self._operand_names(inst):
                    b, _ = _shape_bytes_elems(self.shapes.get(name, ""))
                    op_b += b
                g = _GROUPS_RE.search(inst.rest)
                if g:
                    gs = g.group(1).count(",") + 1
                else:
                    gi = _GROUPS_IOTA_RE.search(inst.rest)
                    gs = int(gi.group(2)) if gi else 1
                total["coll"].append(CollectiveUse(base, op_b, gs, 1,
                                                   inst.shape_str[:64]))
                total["bytes"] += self._instr_bytes(inst)
                continue
            if inst.opcode in ("dot", "convolution"):
                total["flops"] += self._dot_flops(inst)
            ib = self._instr_bytes(inst)
            total["bytes"] += ib
            if ib > 1 << 22:
                k2 = f"{inst.opcode} {inst.shape_str[:48]}"
                total["big"][k2] = total["big"].get(k2, 0) + ib
        self._memo[comp] = total
        return total


def analyze(hlo_text: str) -> dict:
    """Loop-corrected per-device costs + collective summary."""
    mod = HloModule(hlo_text)
    c = mod.cost()
    by_op: Dict[str, dict] = {}
    for u in c["coll"]:
        d = by_op.setdefault(u.op, {"count": 0, "operand_bytes": 0,
                                    "link_bytes": 0})
        d["count"] += u.multiplier
        d["operand_bytes"] += u.operand_bytes * u.multiplier
        d["link_bytes"] += u.link_bytes
    top = sorted(c["coll"], key=lambda u: -u.link_bytes)[:12]
    top_bytes = sorted(c["big"].items(), key=lambda kv: -kv[1])[:12]
    return {
        "flops": float(c["flops"]),
        "bytes": float(c["bytes"]),
        "collectives": {
            "by_op": by_op,
            "total_operand_bytes": sum(v["operand_bytes"]
                                       for v in by_op.values()),
            "total_link_bytes": sum(v["link_bytes"] for v in by_op.values()),
            "count": sum(v["count"] for v in by_op.values()),
            "top": [{"op": u.op, "shape": u.shape, "x": u.multiplier,
                     "group": u.group_size, "link_bytes": u.link_bytes}
                    for u in top],
        },
        "top_bytes": [{"op": k, "bytes": v} for k, v in top_bytes],
    }

"""TPU v5e hardware model (per chip) — the roofline denominators."""

PEAK_BF16 = 197e12       # FLOP/s
PEAK_INT8 = 394e12       # OP/s (MXU int8 = 2x bf16)
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (assignment-specified)
VMEM_BYTES = 128 * 2**20 // 8  # ~16 MiB usable
HBM_BYTES = 16 * 2**30

"""quantlint: quantization-invariant static checker.

Two complementary passes (see README "Static analysis"):
  * `astlint` — AST rules over the repo source (pallas compiler-params via
    the version shim, no magic quant-range literals, no float64, interpret
    escape hatches), with a pluggable rule registry and per-line/per-file
    suppression comments.
  * `dtype_flow` — jaxpr abstract interpretation of representative
    quantized graphs (int8/W4A8 GEMM contracts, paged-attention dequant,
    the PTQ-swapped transformer block, the serving decode step) asserting
    int32 accumulation, scale-applied-exactly-once, and no silent packed
    int4 upcasts.

CLI: `python -m repro.analysis [paths...]` (or `scripts/lint.py`); wired as
a blocking stage in `scripts/ci.sh`.
"""
from repro.analysis.findings import Finding, render_report  # noqa: F401
from repro.analysis.astlint import (RULES, lint_file, lint_paths,  # noqa: F401
                                    rule)
from repro.analysis.dtype_flow import (FLOW_RULES, TraceSpec,  # noqa: F401
                                       check_suite, check_trace)

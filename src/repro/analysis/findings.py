"""Finding records and report rendering for the quantlint checker.

A `Finding` is one rule violation, pointing at a file/line (AST rules) or a
traced-graph equation (dtype-flow rules; `line == 0` and `path` names the
trace). Reports group findings by path and end with a per-rule tally so CI
logs show at a glance which invariant regressed.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from typing import Iterable, List


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str          # repo-relative file, or "<trace:name>" for jaxpr rules
    line: int          # 1-based; 0 for trace-level findings
    rule: str          # registry id, e.g. "magic-quant-literal"
    message: str

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


def render_report(findings: Iterable[Finding], *, fmt: str = "text") -> str:
    fs: List[Finding] = sorted(findings)
    if fmt == "json":
        return json.dumps([dataclasses.asdict(f) for f in fs], indent=2)
    if not fs:
        return "quantlint: 0 findings"
    lines = [f.format() for f in fs]
    tally = Counter(f.rule for f in fs)
    lines.append("")
    lines.append(f"quantlint: {len(fs)} finding(s) — "
                 + ", ".join(f"{r}: {n}" for r, n in sorted(tally.items())))
    return "\n".join(lines)

"""AST-based repo linter: quantization invariants as machine-checked rules.

Each rule inspects one parsed file and yields `Finding`s. Rules live in a
pluggable registry — add one with the `@rule(...)` decorator and it is
picked up by the CLI, `--list-rules`, and the fixture tests automatically.

Suppression: append `# quantlint: disable=<rule-id>[,<rule-id>...]` to the
offending line, or put `# quantlint: disable-file=<rule-id>[,...]` on any
line to silence a rule for the whole file.

Enforced invariants (see README "Static analysis"):
  * pallas-compiler-params — every `pl.pallas_call` passes `compiler_params=`
    built via the `repro.kernels.tpu_compiler_params` version shim.
  * raw-compiler-params   — no direct `pltpu.TPUCompilerParams(...)` /
    `pltpu.CompilerParams(...)` construction outside the shim module.
  * magic-quant-literal   — no bare -128 / -127 / 127 / 15 quant-range
    literals outside `core/quant/qtypes.py`; use `qmin(bits)` / `qmax(bits)`.
  * no-float64            — no float64 dtypes (TPU pipeline is f32/bf16/int).
  * pallas-interpret      — every kernel wrapper plumbs an `interpret=`
    escape hatch into its `pallas_call` (CPU/CI execution path).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, Iterator, List, Optional

from repro.analysis.findings import Finding

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    check: Callable[["FileCtx"], Iterable[Finding]]


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, summary: str):
    """Register a rule. The decorated function maps FileCtx -> Findings."""

    def deco(fn):
        assert rule_id not in RULES, f"duplicate rule id {rule_id!r}"
        RULES[rule_id] = Rule(rule_id, summary, fn)
        return fn

    return deco


# ---------------------------------------------------------------------------
# Per-file context (parse once, share across rules)
# ---------------------------------------------------------------------------

_DISABLE_LINE = re.compile(r"#\s*quantlint:\s*disable=([\w,\- ]+)")
_DISABLE_FILE = re.compile(r"#\s*quantlint:\s*disable-file=([\w,\- ]+)")


class FileCtx:
    def __init__(self, path: Path, source: str, rel: Optional[str] = None):
        self.path = path
        self.rel = (rel or str(path)).replace("\\", "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self._file_disabled = set()
        self._line_disabled: Dict[int, set] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _DISABLE_FILE.search(line)
            if m:
                self._file_disabled |= {r.strip() for r in m.group(1).split(",")}
                continue
            m = _DISABLE_LINE.search(line)
            if m:
                self._line_disabled[i] = {r.strip() for r in m.group(1).split(",")}

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def enclosing_functions(self, node: ast.AST) -> List[ast.FunctionDef]:
        out = []
        cur = self.parent(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append(cur)
            cur = self.parent(cur)
        return out

    def suppressed(self, rule_id: str, line: int) -> bool:
        return (rule_id in self._file_disabled
                or rule_id in self._line_disabled.get(line, set()))

    def in_tree(self, *suffixes: str) -> bool:
        return any(self.rel.endswith(s) for s in suffixes)

    def finding(self, rule_id: str, node: ast.AST, message: str):
        line = getattr(node, "lineno", 0)
        if not self.suppressed(rule_id, line):
            yield Finding(self.rel, line, rule_id, message)


def _callee_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _kw(call: ast.Call, name: str) -> Optional[ast.keyword]:
    for k in call.keywords:
        if k.arg == name:
            return k
    return None


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

_SHIM_FILE = "repro/kernels/__init__.py"
_QTYPES_FILE = "repro/core/quant/qtypes.py"


@rule("pallas-compiler-params",
      "pl.pallas_call must pass compiler_params= built via the "
      "repro.kernels.tpu_compiler_params shim")
def _check_pallas_compiler_params(ctx: FileCtx) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and _callee_name(node) == "pallas_call"):
            continue
        kw = _kw(node, "compiler_params")
        if kw is None:
            yield from ctx.finding(
                "pallas-compiler-params", node,
                "pallas_call without compiler_params= (build them via "
                "repro.kernels.tpu_compiler_params)")
        elif not (isinstance(kw.value, ast.Call)
                  and _callee_name(kw.value) == "tpu_compiler_params"):
            yield from ctx.finding(
                "pallas-compiler-params", kw.value,
                "compiler_params not built via the tpu_compiler_params shim "
                "(raw construction breaks across JAX pallas renames)")


@rule("raw-compiler-params",
      "no pltpu.TPUCompilerParams / pltpu.CompilerParams construction "
      "outside the repro.kernels shim")
def _check_raw_compiler_params(ctx: FileCtx) -> Iterator[Finding]:
    if ctx.in_tree(_SHIM_FILE):
        return
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call)
                and _callee_name(node) in ("TPUCompilerParams",
                                           "CompilerParams")):
            yield from ctx.finding(
                "raw-compiler-params", node,
                f"direct {_callee_name(node)}(...) construction; use "
                "repro.kernels.tpu_compiler_params instead")


# Quant-range literals. Positive 128 alone is *not* banned (it is the
# ubiquitous MXU tile / block size); the banned spellings are the clip
# bounds -128, -127, 127 and the int4 denominator 15.
_BANNED_POS = {127, 127.0, 15, 15.0}     # quantlint: disable=magic-quant-literal
_BANNED_NEG = {127, 127.0, 128, 128.0}   # quantlint: disable=magic-quant-literal


@rule("magic-quant-literal",
      "quant-range literals (-128/-127/127/15) must come from "
      "qtypes.qmin(bits)/qmax(bits)")
def _check_magic_literal(ctx: FileCtx) -> Iterator[Finding]:
    if ctx.in_tree(_QTYPES_FILE):
        return
    negated = set()
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
                and isinstance(node.operand, ast.Constant)
                and type(node.operand.value) in (int, float)
                and node.operand.value in _BANNED_NEG):
            negated.add(node.operand)
            yield from ctx.finding(
                "magic-quant-literal", node,
                f"magic quant-range literal -{node.operand.value!r}; use "
                "qtypes.qmin(bits)")
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Constant) and node not in negated
                and type(node.value) in (int, float)
                and node.value in _BANNED_POS):
            yield from ctx.finding(
                "magic-quant-literal", node,
                f"magic quant-range literal {node.value!r}; use "
                "qtypes.qmax(bits) (or 2**bits - 1 via qtypes helpers)")


@rule("no-float64", "no float64 dtypes anywhere in the pipeline")
def _check_float64(ctx: FileCtx) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64":  # quantlint: disable=no-float64
            yield from ctx.finding(
                "no-float64", node, "float64 dtype (pipeline is "
                "f32/bf16/int; f64 silently disables TPU fast paths)")
        elif isinstance(node, ast.Constant) and node.value == "float64":  # quantlint: disable=no-float64
            yield from ctx.finding(
                "no-float64", node, 'dtype string "float64"')


@rule("pallas-interpret",
      "kernel wrappers must plumb an interpret= escape hatch into "
      "pallas_call")
def _check_interpret(ctx: FileCtx) -> Iterator[Finding]:
    if "/kernels/" not in ctx.rel and not ctx.rel.startswith("kernels/"):
        return
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and _callee_name(node) == "pallas_call"):
            continue
        if _kw(node, "interpret") is None:
            yield from ctx.finding(
                "pallas-interpret", node,
                "pallas_call without interpret= (kernels must keep a CPU "
                "interpret-mode escape hatch)")
            continue
        funcs = ctx.enclosing_functions(node)
        has_param = any(
            any(a.arg == "interpret"
                for a in (f.args.args + f.args.kwonlyargs))
            for f in funcs)
        if not has_param:
            yield from ctx.finding(
                "pallas-interpret", node,
                "enclosing kernel wrapper does not expose an interpret= "
                "parameter (escape hatch must reach callers)")


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def lint_file(path: Path, *, rel: Optional[str] = None,
              rules: Optional[Iterable[str]] = None) -> List[Finding]:
    source = path.read_text()
    try:
        ctx = FileCtx(path, source, rel=rel)
    except SyntaxError as e:
        return [Finding(rel or str(path), e.lineno or 0, "parse-error",
                        f"could not parse: {e.msg}")]
    active = [RULES[r] for r in rules] if rules else list(RULES.values())
    out: List[Finding] = []
    for r in active:
        out.extend(r.check(ctx))
    return out


def iter_python_files(paths: Iterable[str]) -> Iterator[Path]:
    for p in paths:
        pp = Path(p)
        if pp.is_dir():
            yield from sorted(pp.rglob("*.py"))
        elif pp.suffix == ".py":
            yield pp


def lint_paths(paths: Iterable[str],
               rules: Optional[Iterable[str]] = None) -> List[Finding]:
    out: List[Finding] = []
    for f in iter_python_files(paths):
        out.extend(lint_file(f, rel=str(f), rules=rules))
    return out

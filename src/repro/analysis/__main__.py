"""CLI for the quantlint checker.

    python -m repro.analysis [paths...]          # AST lint + dtype-flow
    python -m repro.analysis src --no-flow       # AST rules only
    python -m repro.analysis --flow-only         # jaxpr dtype-flow only
    python -m repro.analysis --list-rules
    python -m repro.analysis src --json          # machine-readable findings

Exit status: 0 if no findings, 1 otherwise (CI gates on this).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="quantization-invariant static checker (quantlint)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to AST-lint (default: src)")
    ap.add_argument("--rules", nargs="*", default=None,
                    help="subset of AST rule ids to run")
    ap.add_argument("--no-flow", action="store_true",
                    help="skip the jaxpr dtype-flow pass")
    ap.add_argument("--flow-only", action="store_true",
                    help="run only the jaxpr dtype-flow pass")
    ap.add_argument("--fast-flow", action="store_true",
                    help="dtype-flow on kernel contracts only (skip the "
                         "model-level traces)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    args = ap.parse_args(argv)

    from repro.analysis import astlint, findings as fmod

    if args.list_rules:
        from repro.analysis.dtype_flow import FLOW_RULES
        for r in astlint.RULES.values():
            print(f"[ast]  {r.id:24s} {r.summary}")
        for rid, summary in FLOW_RULES.items():
            print(f"[flow] {rid:24s} {summary}")
        return 0

    all_findings = []
    if not args.flow_only:
        paths = args.paths or ["src"]
        all_findings.extend(astlint.lint_paths(paths, rules=args.rules))
    if not args.no_flow:
        from repro.analysis.dtype_flow import check_suite
        from repro.analysis.suite import default_specs
        all_findings.extend(check_suite(default_specs(fast=args.fast_flow)))

    print(fmod.render_report(all_findings,
                             fmt="json" if args.json else "text"))
    return 1 if all_findings else 0


if __name__ == "__main__":
    sys.exit(main())

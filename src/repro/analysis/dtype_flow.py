"""jaxpr dtype-flow checker: the quant arithmetic contract, machine-checked.

Traces a representative quantized computation with `jax.make_jaxpr` and
walks the jaxpr with a small abstract interpreter. Every variable carries a
`Flow` state:

  * ``d`` — the *scale balance*: each quantized operand contributes -1
    (one dequant scale still owed); each multiplication by a scale
    contributes +1; a properly dequantized float tensor sits at 0.
  * ``scale`` — the variable is (derived from) a quantization scale. Scales
    are recognized from input tags or in-graph derivation: ``reduce_max`` of
    ``abs(data)`` (the paper's absmax) followed by elementwise arithmetic.
  * ``packed`` — the variable holds nibble-packed int4 storage; only
    arithmetic shifts (sign-extending unpack) may consume it.
  * ``data`` — the variable descends from quantized data. It survives
    dequantization to d = 0, so applying a scale to already-dequantized
    data is read as double-scaling, not scale arithmetic.

Checked invariants:
  * int8-accum        — every int8 x int8 `dot_general` (including inside
    Pallas kernel bodies) accumulates in int32 or float32 via
    `preferred_element_type`, never in int8/bf16.
  * scale-once        — every int8 -> float path applies its dequant
    scale(s) exactly once: a float graph output with d < 0 escaped without
    dequantization; any data tensor reaching d > 0 was double-scaled.
  * scale-mismatch    — add-like ops never combine tensors at different
    scale states (e.g. an int32 accumulator with a dequantized float).
  * packed-int4-upcast — packed int4 storage is never converted to a wider
    dtype or fed to a matmul before the shift-based unpack.
  * nonlinear-on-unscaled — transcendental ops never consume a tensor that
    still owes a dequant scale.

The walker descends through pjit/scan/while/cond/custom_* calls. Pallas
kernel bodies are only scanned structurally (the int8-accum check); their
value flow is checked via the `ref.py` oracles, which tests pin the kernels
to. Closed-over constants are treated as neutral: the contract surface of a
trace is its explicitly tagged arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding

try:  # jax.core is the public home through 0.4.x
    from jax import core as _core
except ImportError:  # pragma: no cover - newer jax
    from jax._src import core as _core

try:
    from jax._src import source_info_util as _siu
except ImportError:  # pragma: no cover
    _siu = None


# ---------------------------------------------------------------------------
# Flow lattice
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Flow:
    d: int = 0            # scale balance (-1 per pending dequant scale)
    scale: bool = False   # is (derived from) a quant scale
    packed: bool = False  # nibble-packed int4 storage
    absval: bool = False  # |data| (absmax precursor)
    data: bool = False    # came from quantized data (survives dequant to d=0,
                          # so `dequantized * scale` reads as double-scaling
                          # rather than scale arithmetic)


NEUTRAL = Flow()


def _strong(f: Flow) -> bool:
    return f.d != 0 or f.scale or f.packed or f.data


def _join(a: Flow, b: Flow) -> Flow:
    if not _strong(a):
        return b
    return a


def _arith_scale(a: Flow, b: Flow) -> bool:
    """mul/div result stays a scale when both operands are scales or a
    scale meets a neutral constant (e.g. `2.0 * absmax / (2^n - 1)`)."""
    if a.scale and b.scale:
        return True
    return (a.scale and not _strong(b)) or (b.scale and not _strong(a))


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """One representative graph plus the quant contract of its inputs.

    ``tags`` maps flat argument-leaf index -> "quant" | "packed" | "scale"
    (untagged leaves are neutral). Build specs via `repro.analysis.suite`.
    """

    name: str
    fn: Callable
    args: tuple
    tags: Dict[int, str]


_ELEMENTWISE_PASS = {
    "neg", "sign", "floor", "ceil", "round", "real", "imag", "copy",
    "stop_gradient", "reduce_precision", "convert_element_type",
    "sharding_constraint", "device_put", "is_finite",
}
_STRUCTURAL_PASS = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "slice",
    "dynamic_slice", "rev", "gather", "pad", "expand_dims", "cumsum",
    "cummax", "cummin", "sort", "split",
}
_NONLINEAR = {
    "exp", "exp2", "log", "log1p", "expm1", "tanh", "logistic", "erf",
    "erfc", "sin", "cos", "tan", "rsqrt", "sqrt", "cbrt",
}
_ADD_LIKE = {
    "add", "sub", "max", "min", "select_n", "concatenate",
    "dynamic_update_slice", "scatter", "scatter-add", "scatter-mul",
    "scatter-max", "scatter-min", "clamp", "nextafter",
}
_NEUTRAL_OUT = {
    "eq", "ne", "lt", "le", "gt", "ge", "iota", "argmax", "argmin",
    "reduce_and", "reduce_or", "not", "rng_bit_generator", "random_bits",
    "random_seed", "random_wrap", "random_unwrap",
}
_SHIFTS = {"shift_left", "shift_right_arithmetic", "shift_right_logical"}
_REDUCE_PASS = {"reduce_sum", "reduce_prod", "reduce_min", "cumlogsumexp"}


def _eqn_loc(eqn) -> str:
    if _siu is not None:
        try:
            return _siu.summarize(eqn.source_info)
        except Exception:
            pass
    return "<unknown>"


def _float_dtype(aval) -> bool:
    return jnp.issubdtype(aval.dtype, jnp.floating)


def _sub_jaxprs(obj):
    """Yield every Jaxpr reachable from an eqn param value."""
    if isinstance(obj, _core.Jaxpr):
        yield obj
    elif isinstance(obj, _core.ClosedJaxpr):
        yield obj.jaxpr
    elif isinstance(obj, (tuple, list)):
        for o in obj:
            yield from _sub_jaxprs(o)


class _FlowChecker:
    def __init__(self, trace_name: str):
        self.trace = trace_name
        self.findings: List[Finding] = []

    # -- findings ----------------------------------------------------------
    def _emit(self, rule: str, eqn, message: str):
        self.findings.append(Finding(
            f"<trace:{self.trace}>", 0, rule,
            f"{message} (at {_eqn_loc(eqn)})"))

    # -- structural int8-accum check (descends everywhere, incl. pallas) ---
    def check_dots(self, jaxpr: _core.Jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "dot_general":
                dts = [v.aval.dtype for v in eqn.invars]
                if all(dt == jnp.int8 for dt in dts):
                    out = eqn.outvars[0].aval.dtype
                    if out not in (jnp.int32, jnp.float32):
                        self._emit(
                            "int8-accum", eqn,
                            "int8 x int8 dot_general accumulates in "
                            f"{out}; pass preferred_element_type="
                            "int32 (or float32)")
            for p in eqn.params.values():
                for sub in _sub_jaxprs(p):
                    self.check_dots(sub)

    # -- value-flow interpretation -----------------------------------------
    def run(self, closed: _core.ClosedJaxpr,
            in_flows: Sequence[Flow]) -> List[Flow]:
        jaxpr = closed.jaxpr
        env: Dict[object, Flow] = {v: NEUTRAL for v in jaxpr.constvars}
        assert len(jaxpr.invars) == len(in_flows), \
            (len(jaxpr.invars), len(in_flows))
        env.update(zip(jaxpr.invars, in_flows))

        def get(v) -> Flow:
            if isinstance(v, _core.Literal):
                return NEUTRAL
            return env.get(v, NEUTRAL)

        for eqn in jaxpr.eqns:
            outs = self._eval_eqn(eqn, [get(v) for v in eqn.invars])
            for v, f in zip(eqn.outvars, outs):
                env[v] = f
        return [get(v) for v in jaxpr.outvars]

    def _run_inner(self, inner, eqn, ins: Sequence[Flow]) -> List[Flow]:
        if isinstance(inner, _core.Jaxpr):
            inner = _core.ClosedJaxpr(inner, ())
        n = len(inner.jaxpr.invars)
        # align on the tail: some call prims prepend consts to invars
        flows = list(ins)[-n:] if len(ins) >= n \
            else [NEUTRAL] * (n - len(ins)) + list(ins)
        return self.run(inner, flows)

    def _combine(self, eqn, ins: Sequence[Flow]) -> Flow:
        """add/select/concat/scatter-like: all strong operands must agree."""
        strong = [f for f in ins if _strong(f)]
        ds = {f.d for f in strong if not f.scale}
        if len(ds) > 1:
            self._emit(
                "scale-mismatch", eqn,
                f"{eqn.primitive.name} combines tensors at different scale "
                f"states (balances {sorted(ds)}); apply dequant scales "
                "consistently before mixing")
        out = NEUTRAL
        for f in strong:
            out = _join(out, f)
        return dataclasses.replace(out, absval=False)

    def _eval_eqn(self, eqn, ins: Sequence[Flow]) -> List[Flow]:
        p = eqn.primitive.name
        n_out = len(eqn.outvars)

        # -- higher-order primitives ---------------------------------------
        if p == "scan":
            return self._eval_scan(eqn, ins)
        if p in ("while", "while_loop"):
            return self._eval_while(eqn, ins)
        if p == "cond":
            branches = eqn.params["branches"]
            outs = None
            for br in branches:
                o = self._run_inner(br, eqn, ins[1:])
                outs = o if outs is None else [_join(a, b)
                                               for a, b in zip(outs, o)]
            return outs or [NEUTRAL] * n_out
        if p == "pallas_call":
            # bodies operate on Refs; value flow is validated on the ref
            # oracles instead. Outputs: neutral.
            return [NEUTRAL] * n_out
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in eqn.params:
                inner = eqn.params[key]
                if isinstance(inner, (_core.Jaxpr, _core.ClosedJaxpr)):
                    return self._run_inner(inner, eqn, ins)

        # -- arithmetic ----------------------------------------------------
        if p == "mul" or p == "dot_general":
            a, b = ins[0], ins[1]
            for f in (a, b):
                if f.packed:
                    self._emit(
                        "packed-int4-upcast", eqn,
                        f"packed int4 storage consumed by {p} before "
                        "shift-based unpack")
            sc = _arith_scale(a, b)
            out = Flow(d=a.d + b.d, scale=sc,
                       data=(a.data or b.data) and not sc)
            if out.d > 0 and not out.scale:
                self._emit(
                    "scale-once", eqn,
                    f"double-scaling: {p} leaves a data tensor with "
                    f"scale balance +{out.d} (a dequant scale applied "
                    "more than once)")
            return [out] * n_out
        if p == "div":
            a, b = ins[0], ins[1]
            sc = _arith_scale(a, b)
            # dividing by a scale quantizes: the result is data again
            data = not sc and (a.data or b.data or b.scale)
            return [Flow(d=a.d - b.d, scale=sc, data=data)] * n_out
        if p == "integer_pow":
            y = eqn.params.get("y", 1)
            return [Flow(d=ins[0].d * y, scale=ins[0].scale)] * n_out
        if p == "abs":
            f = ins[0]
            return [dataclasses.replace(
                f, absval=(f.d == 0 and not f.scale))] * n_out
        if p in ("reduce_max", "reduce_min") and ins[0].absval:
            return [Flow(d=ins[0].d + 1, scale=True)] * n_out
        if p in ("reduce_max", "reduce_min") or p in _REDUCE_PASS:
            return [dataclasses.replace(ins[0], absval=False)] * n_out
        if p in _SHIFTS:
            if ins[0].packed:  # sign-extending unpack -> int4 values
                return [Flow(d=-1, data=True)] * n_out
            return [ins[0]] * n_out
        if p in ("and", "or", "xor"):
            return [_join(ins[0], ins[1])] * n_out
        if p in _NONLINEAR:
            f = ins[0]
            if f.d != 0 and not f.scale:
                self._emit(
                    "nonlinear-on-unscaled", eqn,
                    f"{p} applied to a tensor that still owes "
                    f"{-f.d} dequant scale(s)")
            return [Flow(d=f.d, scale=f.scale)] * n_out
        if p == "clamp":
            return [ins[1]] * n_out
        if p == "convert_element_type":
            f = ins[0]
            new = eqn.outvars[0].aval.dtype
            if f.packed and new != jnp.int8:
                self._emit(
                    "packed-int4-upcast", eqn,
                    f"packed int4 storage converted to {new} before "
                    "shift-based unpack (nibbles silently reinterpreted)")
                f = dataclasses.replace(f, packed=False)
            return [f] * n_out
        if p in _ADD_LIKE:
            return [self._combine(eqn, ins)] * n_out
        if p in _NEUTRAL_OUT:
            return [NEUTRAL] * n_out
        if p in _ELEMENTWISE_PASS or p in _STRUCTURAL_PASS:
            return [ins[0] if ins else NEUTRAL] * n_out

        # default: propagate the strongest input, flag nothing
        out = NEUTRAL
        for f in ins:
            out = _join(out, f)
        return [dataclasses.replace(out, absval=False)] * n_out

    def _eval_scan(self, eqn, ins: Sequence[Flow]) -> List[Flow]:
        closed = eqn.params["jaxpr"]
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        consts = list(ins[:nc])
        carry = list(ins[nc:nc + ncar])
        xs = list(ins[nc + ncar:])
        outs: List[Flow] = []
        for _ in range(3):  # tiny fixpoint over the carry
            outs = self._run_inner(closed, eqn, consts + carry + xs)
            carry_out = outs[:ncar]
            if carry_out == carry:
                break
            carry = [_join(a, b) for a, b in zip(carry_out, carry)]
        return outs

    def _eval_while(self, eqn, ins: Sequence[Flow]) -> List[Flow]:
        body = eqn.params["body_jaxpr"]
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        consts = list(ins[cn:cn + bn])
        carry = list(ins[cn + bn:])
        for _ in range(3):
            outs = self._run_inner(body, eqn, consts + carry)
            if outs == carry:
                break
            carry = [_join(a, b) for a, b in zip(outs, carry)]
        return carry


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

_TAG_FLOWS = {
    "quant": Flow(d=-1, data=True),
    "packed": Flow(d=-1, packed=True, data=True),
    "scale": Flow(d=1, scale=True),
}


def check_trace(spec: TraceSpec) -> List[Finding]:
    """Trace `spec.fn(*spec.args)` and check the quant dtype contract."""
    closed = jax.make_jaxpr(spec.fn)(*spec.args)
    checker = _FlowChecker(spec.name)
    checker.check_dots(closed.jaxpr)

    in_flows = []
    for i, _ in enumerate(closed.jaxpr.invars):
        tag = spec.tags.get(i)
        in_flows.append(_TAG_FLOWS.get(tag, NEUTRAL) if tag else NEUTRAL)
    out_flows = checker.run(closed, in_flows)

    for i, (var, f) in enumerate(zip(closed.jaxpr.outvars, out_flows)):
        if f.scale or not _float_dtype(var.aval):
            continue  # scales and integer storage legitimately carry debt
        if f.d < 0:
            checker.findings.append(Finding(
                f"<trace:{spec.name}>", 0, "scale-once",
                f"float output #{i} escaped with {-f.d} dequant scale(s) "
                "never applied (scale-free int8->float path)"))
        elif f.d > 0:
            checker.findings.append(Finding(
                f"<trace:{spec.name}>", 0, "scale-once",
                f"float output #{i} is double-scaled (balance +{f.d})"))
    return sorted(set(checker.findings))


def check_suite(specs: Sequence[TraceSpec]) -> List[Finding]:
    out: List[Finding] = []
    for spec in specs:
        out.extend(check_trace(spec))
    return out


FLOW_RULES = {
    "int8-accum": "int8 x int8 matmuls accumulate in int32/f32 via "
                  "preferred_element_type",
    "scale-once": "each dequant scale applied exactly once on every "
                  "int8->float path",
    "scale-mismatch": "no mixing of tensors at different scale states",
    "packed-int4-upcast": "packed int4 never upcast before shift-unpack",
    "nonlinear-on-unscaled": "no transcendental on not-yet-dequantized data",
}

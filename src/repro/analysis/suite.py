"""Representative quantized graphs for the dtype-flow checker.

Each builder returns a `TraceSpec` over the *real* production code paths:
the kernel ref oracles (the numerical contract the Pallas kernels are
pinned to), the jitted Pallas kernels themselves (structural int8-accum
check inside the kernel bodies), the PTQ-swapped transformer block, and the
paged-serving decode step — the same path `benchmarks/bench_serving.py`
drives through the continuous-batching engine.

Input tagging is automatic for pytree arguments (`auto_tags`): QTensor
leaves tag as quant data / per-channel scales, int8 pool pages as quant
data, uint8 pool pages as packed int4 data (the nibble pages of a
kv_bits=4 pool — the packed-int4-upcast invariant bites on them),
`k_s`/`v_s`/`*scale*` float leaves as scales.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.dtype_flow import TraceSpec
from repro.core.quant.qtypes import QTensor

_KEY_ENTRIES = jax.tree_util


def _key_name(key) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(key, attr):
            return str(getattr(key, attr))
    return str(key)


def _resolve(obj, keys):
    """Walk a key path (minus the final key) back into the original tree."""
    for k in keys:
        try:
            if hasattr(k, "key"):
                obj = obj[k.key]
            elif hasattr(k, "idx"):
                obj = obj[k.idx]
            elif hasattr(k, "name"):
                obj = getattr(obj, k.name)
            else:
                return None
        except Exception:
            return None
    return obj


def auto_tags(args: tuple, overrides: Dict[int, str] = None) -> Dict[int, str]:
    """Flat-leaf-index -> tag for the quant contract of `args`."""
    flat, _ = jax.tree_util.tree_flatten_with_path(args)
    tags: Dict[int, str] = {}
    for i, (path, leaf) in enumerate(flat):
        last = _key_name(path[-1]) if path else ""
        parent = _resolve(args, path[:-1]) if path else None
        if isinstance(parent, QTensor):
            if last == "data":
                tags[i] = "packed" if parent.is_packed else "quant"
            elif last == "scale":
                tags[i] = "scale"
            continue
        dtype = getattr(leaf, "dtype", None)
        if dtype == jnp.int8:
            tags[i] = "quant"
        elif dtype == jnp.uint8:
            tags[i] = "packed"       # nibble-packed int4 KV pages
        elif (dtype is not None and jnp.issubdtype(dtype, jnp.floating)
              and ("scale" in last or last in ("k_s", "v_s"))):
            tags[i] = "scale"
    if overrides:
        tags.update(overrides)
    return tags


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Kernel-contract graphs
# ---------------------------------------------------------------------------

def spec_int8_gemm() -> TraceSpec:
    from repro.kernels import ref
    args = (_sds((16, 128), jnp.int8), _sds((128, 64), jnp.int8),
            _sds((16, 1), jnp.float32), _sds((1, 64), jnp.float32))
    return TraceSpec("int8_gemm", ref.int8_matmul_ref, args,
                     {0: "quant", 1: "quant", 2: "scale", 3: "scale"})


def spec_int8_gemm_kernel() -> TraceSpec:
    from repro.kernels import int8_gemm
    args = (_sds((32, 128), jnp.int8), _sds((128, 128), jnp.int8),
            _sds((32, 1), jnp.float32), _sds((1, 128), jnp.float32))
    return TraceSpec("int8_gemm_pallas",
                     partial(int8_gemm.int8_matmul, bm=32, bn=128, bk=128),
                     args, {0: "quant", 1: "quant", 2: "scale", 3: "scale"})


def spec_w4a8_gemm() -> TraceSpec:
    from repro.kernels import ref
    args = (_sds((8, 256), jnp.int8), _sds((128, 64), jnp.int8),
            _sds((8, 1), jnp.float32), _sds((2, 64), jnp.float32))
    return TraceSpec("w4a8_gemm",
                     partial(ref.w4a8_matmul_ref, group_size=128), args,
                     {0: "quant", 1: "packed", 2: "scale", 3: "scale"})


def spec_w4a8_gemm_kernel() -> TraceSpec:
    from repro.kernels import w4a8_gemm
    args = (_sds((32, 256), jnp.int8), _sds((128, 128), jnp.int8),
            _sds((32, 1), jnp.float32), _sds((2, 128), jnp.float32))
    return TraceSpec("w4a8_gemm_pallas",
                     partial(w4a8_gemm.w4a8_matmul, group_size=128,
                             bm=32, bn=128),
                     args, {0: "quant", 1: "packed", 2: "scale", 3: "scale"})


def spec_paged_attn_dequant() -> TraceSpec:
    from repro.kernels import paged_attn
    b, nq, nkv, hd, page, n_pages, w = 2, 4, 2, 32, 8, 5, 2
    args = (_sds((b, nq, hd), jnp.float32),
            _sds((n_pages, page, nkv, hd), jnp.int8),
            _sds((n_pages, page, nkv, hd), jnp.int8),
            _sds((n_pages, nkv), jnp.float32),
            _sds((n_pages, nkv), jnp.float32),
            _sds((b, w), jnp.int32), _sds((b,), jnp.int32))
    return TraceSpec("paged_attn_dequant",
                     paged_attn.paged_decode_attention_ref, args,
                     {1: "quant", 2: "quant", 3: "scale", 4: "scale"})


def spec_paged_prefill_dequant() -> TraceSpec:
    """The chunked-prefill attention oracle: chunk queries against int8
    pages, per-(page, head) dequant on the read path."""
    from repro.kernels import paged_prefill
    b, c, nq, nkv, hd, page, n_pages, w = 2, 16, 4, 2, 32, 8, 7, 3
    args = (_sds((b, c, nq, hd), jnp.float32),
            _sds((n_pages, page, nkv, hd), jnp.int8),
            _sds((n_pages, page, nkv, hd), jnp.int8),
            _sds((n_pages, nkv), jnp.float32),
            _sds((n_pages, nkv), jnp.float32),
            _sds((b, w), jnp.int32), _sds((b,), jnp.int32),
            _sds((b,), jnp.int32))
    return TraceSpec("paged_prefill_dequant",
                     paged_prefill.paged_prefill_attention_ref, args,
                     {1: "quant", 2: "quant", 3: "scale", 4: "scale"})


# ---------------------------------------------------------------------------
# Model-level graphs
# ---------------------------------------------------------------------------

def _tiny_ptq_model(qname: str = "int8"):
    from repro.configs import get_arch, reduced
    from repro.core.quant import calibrate, ptq
    from repro.core.quant.qtypes import preset
    from repro.models import transformer
    cfg = reduced(get_arch("pangu_1b"))
    qcfg = preset(qname)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        np.arange(2 * 8, dtype=np.int32).reshape(2, 8) % cfg.vocab)}
    stats = calibrate.collect_stats(params, [batch], cfg)
    pq = ptq.quantize_model(params, cfg, qcfg, stats)
    return cfg, qcfg, pq, batch


def spec_ptq_block(qname: str = "int8") -> TraceSpec:
    """The PTQ-swapped transformer block: quantize-act -> int GEMM ->
    dequant epilogue inside the scanned block stack (impl="xla")."""
    from repro.models import transformer
    cfg, qcfg, pq, batch = _tiny_ptq_model(qname)

    def fwd(params, batch):
        logits, _ = transformer.forward_train(params, batch, cfg, qcfg=qcfg,
                                              impl="xla", remat=False)
        return logits

    args = (pq, batch)
    return TraceSpec(f"ptq_block_{qname}", fwd, args, auto_tags(args))


def spec_serving_decode(kv_bits: int = 8) -> TraceSpec:
    """The paged serving decode step (the path bench_serving.py measures):
    int8 (or packed-int4 uint8, kv_bits=4) page pools + per-(page, head)
    scales through decode_step_paged — the int4 trace makes the
    packed-int4-never-upcast-before-shift invariant bite on serving."""
    from repro.configs import get_arch, reduced
    from repro.models import transformer
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, page, n_pages, w = 2, 8, 5, 2
    pools = transformer.init_paged_pools(cfg, n_pages, page, kv_bits=kv_bits)
    page_table = jnp.ones((b, w), jnp.int32)
    tokens = jnp.zeros((b,), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)

    def step(params, pools, page_table, tokens, pos):
        logits, _ = transformer.decode_step_paged(
            params, pools, page_table, tokens, pos, cfg, paged_impl="xla")
        return logits

    name = ("serving_decode" if kv_bits == 8
            else f"serving_decode_int{kv_bits}")
    args = (params, pools, page_table, tokens, pos)
    return TraceSpec(name, step, args, auto_tags(args))


def spec_serving_prefill_chunk(kv_bits: int = 8) -> TraceSpec:
    """The chunked mixed prefill/decode step (the chunked-engine path
    bench_serving.py measures): fused quantize-on-write into int8 (or
    packed-int4, kv_bits=4) pages — scale-once and int8-accum must hold
    through write_chunk's dequant -> merge -> requantize (for int4: the
    shift-unpack, then pack-on-store) as well as the attention read."""
    from repro.configs import get_arch, reduced
    from repro.models import transformer
    from repro.serving.kv_pool import chunk_window_pages
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, page, n_pages, w, c = 2, 8, 9, 3, 16
    wc = chunk_window_pages(c, page)
    pools = transformer.init_paged_pools(cfg, n_pages, page, kv_bits=kv_bits)
    page_table = jnp.ones((b, w), jnp.int32)
    window_rows = jnp.ones((b, wc), jnp.int32)
    tokens = jnp.zeros((b, c), jnp.int32)
    q_start = jnp.zeros((b,), jnp.int32)
    n_new = jnp.full((b,), c, jnp.int32)

    def step(params, pools, page_table, window_rows, tokens, q_start, n_new):
        logits, _ = transformer.prefill_chunk_paged(
            params, pools, page_table, window_rows, tokens, q_start, n_new,
            cfg, paged_impl="xla")
        return logits

    name = ("serving_prefill_chunk" if kv_bits == 8
            else f"serving_prefill_chunk_int{kv_bits}")
    args = (params, pools, page_table, window_rows, tokens, q_start, n_new)
    return TraceSpec(name, step, args, auto_tags(args))


def spec_serving_prefill_chunk_cached() -> TraceSpec:
    """The prefix-cache-hit mixed step: a prefill chunk whose page table
    maps previously-cached int8 pages for the shared prefix (q_start > 0,
    the chunk writes only fresh tail pages). Scale-once and int8-accum must
    hold when the attention read crosses pages this request never wrote —
    the cached pages' per-(page, head) scales travel with the page."""
    from repro.configs import get_arch, reduced
    from repro.models import transformer
    from repro.serving.kv_pool import chunk_window_pages
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, page, n_pages, w, c = 2, 8, 9, 4, 16
    wc = chunk_window_pages(c, page)
    pools = transformer.init_paged_pools(cfg, n_pages, page, kv_bits=8)
    # rows 1..2 are another request's cached prefix pages; rows 3.. are this
    # request's fresh tail pages — the write window starts past the hits
    page_table = jnp.asarray(
        np.arange(1, 1 + w, dtype=np.int32)[None].repeat(b, 0))
    window_rows = jnp.asarray(
        np.arange(3, 3 + wc, dtype=np.int32)[None].repeat(b, 0))
    tokens = jnp.zeros((b, c), jnp.int32)
    q_start = jnp.full((b,), 2 * page, jnp.int32)    # 2 pages served by cache
    n_new = jnp.full((b,), c, jnp.int32)

    def step(params, pools, page_table, window_rows, tokens, q_start, n_new):
        logits, _ = transformer.prefill_chunk_paged(
            params, pools, page_table, window_rows, tokens, q_start, n_new,
            cfg, paged_impl="xla")
        return logits

    args = (params, pools, page_table, window_rows, tokens, q_start, n_new)
    return TraceSpec("serving_prefill_chunk_cached", step, args,
                     auto_tags(args))


def spec_serving_verify_step() -> TraceSpec:
    """The speculative-verify step: a k+1-token draft window (unaligned —
    here C = 5) scored read-only by the multi-query chunk-attention read
    (raw window K/V spliced over the gathered int8 pages) against a page
    table holding pages this request never wrote (a cached/previously-
    committed prefix), then the accepted prefix committed through the
    fused quantize-on-write path. Each cached page's per-(page, head)
    scale must be applied exactly once on the read side, and the commit's
    requantization must keep int8 storage dtypes."""
    from repro.configs import get_arch, reduced
    from repro.models import transformer
    from repro.serving import kv_pool
    cfg = reduced(get_arch("pangu_1b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, page, n_pages, w, c = 2, 8, 9, 4, 5
    wc = kv_pool.verify_window_pages(c, page)
    pools = transformer.init_paged_pools(cfg, n_pages, page, kv_bits=8)
    # rows 1..2 hold the committed prefix (written by earlier steps, so
    # the verify read never re-rounds them); the window starts mid-page 3
    page_table = jnp.asarray(
        np.arange(1, 1 + w, dtype=np.int32)[None].repeat(b, 0))
    window_rows = jnp.asarray(
        np.arange(3, 3 + wc, dtype=np.int32)[None].repeat(b, 0))
    tokens = jnp.zeros((b, c), jnp.int32)
    q_start = jnp.full((b,), 2 * page + 3, jnp.int32)   # unaligned start
    n_new = jnp.full((b,), c, jnp.int32)
    n_keep = jnp.full((b,), 3, jnp.int32)               # accept 2 + bonus

    def step(params, pools, page_table, window_rows, tokens, q_start,
             n_new, n_keep):
        logits, kv_win = transformer.verify_step_paged(
            params, pools, page_table, tokens, q_start, n_new,
            cfg, paged_impl="xla")
        out = {}
        for i in pools:
            kw, vw = kv_win[i]
            out[i] = jax.vmap(kv_pool.write_chunk,
                              in_axes=(0, 0, 0, None, None, None))(
                pools[i], kw, vw, window_rows, q_start, n_keep)
        return logits, out

    args = (params, pools, page_table, window_rows, tokens, q_start,
            n_new, n_keep)
    return TraceSpec("serving_verify_step", step, args, auto_tags(args))


def default_specs(*, fast: bool = False) -> List[TraceSpec]:
    specs = [
        spec_int8_gemm(),
        spec_int8_gemm_kernel(),
        spec_w4a8_gemm(),
        spec_w4a8_gemm_kernel(),
        spec_paged_attn_dequant(),
        spec_paged_prefill_dequant(),
    ]
    if not fast:
        specs.append(spec_ptq_block("int8"))
        specs.append(spec_ptq_block("w4a8"))
        specs.append(spec_serving_decode())
        specs.append(spec_serving_prefill_chunk())
        specs.append(spec_serving_prefill_chunk_cached())
        specs.append(spec_serving_verify_step())
        specs.append(spec_serving_decode(kv_bits=4))
        specs.append(spec_serving_prefill_chunk(kv_bits=4))
    return specs

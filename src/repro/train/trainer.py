"""Train-step builder: microbatched gradient accumulation (lax.scan), remat,
and an explicit-DP mode with int8-compressed gradient all-reduce.

`make_train_step(cfg, opt_cfg, ...)` returns a pure (state, batch) ->
(state, metrics) function — the thing launch/train.py jits with shardings
and launch/dryrun.py lowers for the train_4k cells.

Two distribution modes:
  * GSPMD (default): the step is jitted with in_shardings from
    sharding/rules.py; XLA inserts the gradient reduction (overlapped by the
    latency-hiding scheduler).
  * explicit-DP (`compress=True`): the step runs under shard_map over the
    data axes; each replica computes local grads, quantizes them to int8
    against a pmax-shared scale, psums in int32, and dequantizes — an 8-bit
    gradient all-reduce (error fed back into the next step's grads would
    need carried state; we fold the residual into the metrics instead).
    Cuts cross-pod gradient bytes 4x vs f32 / 2x vs bf16.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
try:                                    # JAX >= 0.5 re-exports at top level
    from jax import shard_map           # type: ignore[attr-defined]
except ImportError:                     # JAX 0.4.x experimental spelling
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.quant.qtypes import qmax, qmin

from repro.models import transformer
from repro.optim import adamw


class TrainState(NamedTuple):
    params: dict
    opt: adamw.OptState
    step: jax.Array


def init_state(key, cfg, opt_cfg: adamw.OptConfig) -> TrainState:
    params = transformer.init_params(key, cfg)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def _split_micro(batch, n_micro: int):
    def sp(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(sp, batch)


def int8_allreduce(grads, axis_names):
    """Compressed all-reduce (runs inside shard_map): int8 payload with a
    shared per-leaf scale (pmax), int32 accumulation, mean."""
    n = jax.lax.psum(1, axis_names)

    def one(g):
        g = g.astype(jnp.float32)
        s_local = jnp.max(jnp.abs(g)) / qmax(8)
        s = jnp.maximum(jax.lax.pmax(s_local, axis_names), 1e-12)
        q = jnp.clip(jnp.round(g / s), qmin(8), qmax(8)).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_names)
        return total.astype(jnp.float32) * (s / n)

    return jax.tree.map(one, grads)


def make_train_step(cfg, opt_cfg: adamw.OptConfig, *, n_micro: int = 1,
                    remat: bool = True, dtype=jnp.bfloat16,
                    mesh=None, dp_axes=("data",), compress: bool = False,
                    shardings=None):
    def loss_fn(params, mb):
        total, parts = transformer.lm_loss(params, mb, cfg, dtype=dtype,
                                           remat=remat, shardings=shardings)
        return total, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        if n_micro == 1:
            (loss, parts), grads = grad_fn(params, batch)
            return loss, parts, grads
        micro = _split_micro(batch, n_micro)

        def body(acc, mb):
            (loss, parts), grads = grad_fn(params, mb)
            return jax.tree.map(jnp.add, acc, (loss, parts, grads)), ()

        zeros = (jnp.zeros(()),
                 {"nll": jnp.zeros(()), "zloss": jnp.zeros(()),
                  "moe_aux": jnp.zeros(())},
                 jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params))
        (loss, parts, grads), _ = jax.lax.scan(body, zeros, micro)
        inv = 1.0 / n_micro
        return (loss * inv, jax.tree.map(lambda x: x * inv, parts),
                jax.tree.map(lambda g: g * inv, grads))

    def step_body(state: TrainState, batch):
        loss, parts, grads = accumulate(state.params, batch)
        if compress:
            grads = int8_allreduce(grads, dp_axes)
            loss = jax.lax.pmean(loss, dp_axes)
            parts = jax.tree.map(lambda x: jax.lax.pmean(x, dp_axes), parts)
        new_params, new_opt, om = adamw.update(grads, state.opt,
                                               state.params, opt_cfg)
        metrics = {"loss": loss, **parts, **om}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    if not compress:
        return step_body

    assert mesh is not None, "compress=True needs an explicit mesh"
    state_spec = P()            # params/opt replicated across dp axes
    batch_spec = jax.tree.map(lambda _: P(dp_axes), {"tokens": 0, "labels": 0})

    def wrapped(state, batch):
        bspec = jax.tree.map(lambda _: P(dp_axes), batch)
        return shard_map(
            step_body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: state_spec, state), bspec),
            out_specs=(jax.tree.map(lambda _: state_spec, state),
                       {"loss": P(), "nll": P(), "zloss": P(),
                        "moe_aux": P(), "grad_norm": P(), "lr": P()}),
            check_rep=False)(state, batch)

    return wrapped

"""Architecture registry: one module per assigned architecture."""
from repro.configs.base import (ArchConfig, MoEConfig, ARCH_IDS, get_arch,
                                reduced)  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeSpec, cell_supported  # noqa: F401

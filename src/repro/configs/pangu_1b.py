"""Assigned architecture config: openPangu-Embedded-1B (paper subject, proxy)

Proxy config for the paper's 1B subject (checkpoint unavailable
offline): dense LLaMA-class GQA decoder of ~1B params.
[arXiv:2505.22375 class; proxy]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="pangu_1b",
    family="dense",
    n_layers=20,
    d_model=1536,
    n_heads=12,
    n_kv_heads=4,
    head_dim=128,
    d_ff=5632,
    vocab=153376,
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2505.22375 class; proxy",
)

"""Assigned architecture config: musicgen-medium [audio]

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048; decoder-only
over EnCodec tokens. [arXiv:2306.05284; hf]. Codec frontend is a stub:
input_specs() supplies precomputed frame embeddings (B, S, d).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="musicgen_medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    act="gelu",
    frontend="embeddings",
    rope_theta=10000.0,
    source="arXiv:2306.05284; hf",
)

"""Assigned architecture config: mixtral-8x22b [moe]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768; 8 experts
top-2, sliding-window attention (4096). [arXiv:2401.04088; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral_8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    pattern=("moe",),
    sliding_window=4096,
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=8, top_k=2),
    source="arXiv:2401.04088; hf",
)

"""Assigned architecture config: llama-3.2-vision-90b [vlm]

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; cross-attn
image layers every 5th position. [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]. Vision frontend is a stub: input_specs() supplies
precomputed patch embeddings as cross-attention context.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama32_vision_90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    pattern=("self", "self", "self", "self", "cross"),
    act="swiglu",
    rope_theta=500000.0,
    frontend="tokens+image",
    n_ctx_tokens=1024,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)

"""Assigned architecture config: nemotron-4-15b [dense]

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000; GQA,
squared-ReLU MLP (ungated). [arXiv:2402.16819; unverified]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="nemotron4_15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    act="squared_relu",
    rope_theta=10000.0,
    source="arXiv:2402.16819; unverified",
)

"""Architecture configuration schema + registry.

Every assigned architecture is a `ArchConfig` instance in its own module
(`configs/<id>.py`), selectable via ``--arch <id>`` in the launchers. The
model stack (models/transformer.py) is entirely driven by `pattern`: the
repeating block sequence scanned over `n_layers // len(pattern)` groups.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    impl: str = "dropping"          # "dense" (oracle) | "dropping" (deployed)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    pattern: Tuple[str, ...] = ("self",)
    act: str = "swiglu"             # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0         # 0 = full causal attention
    moe: Optional[MoEConfig] = None
    # SSM / hybrid / xLSTM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2             # mamba inner = ssm_expand * d_model
    ssm_chunk: int = 256            # chunked selective-scan block
    xlstm_proj: int = 2             # mLSTM up-projection factor
    # Modality frontends (stubs per assignment)
    frontend: str = "tokens"        # tokens | embeddings | tokens+image
    n_ctx_tokens: int = 0           # vlm: image tokens (cross-attn context)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""                # provenance tag from the assignment

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: n_layers {self.n_layers} % pattern {len(self.pattern)}"
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0

    # -- derived ------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:      # mamba branch inner width
        return self.ssm_expand * self.d_model

    @property
    def uses_attention(self) -> bool:
        return any(b in ("self", "moe", "cross", "hybrid") for b in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: bounded (or no) attention state."""
        attn_blocks = [b for b in self.pattern if b in ("self", "moe", "cross",
                                                        "hybrid")]
        return (not attn_blocks) or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        nq, nkv = self.n_heads, self.n_kv_heads
        per = {}
        attn = d * (nq + 2 * nkv) * hd + nq * hd * d
        mlp_mult = 3 if self.act == "swiglu" else 2
        per["self"] = attn + mlp_mult * d * ff
        per["cross"] = attn + mlp_mult * d * ff
        if self.moe:
            per["moe"] = attn + self.moe.num_experts * mlp_mult * d * ff \
                + d * self.moe.num_experts
        di, n = self.d_inner, self.ssm_state
        per["hybrid"] = per["self"] + (2 * d * di + di * (2 * n + 8) + di * d
                                       + di * self.ssm_conv)
        dm = self.xlstm_proj * d
        per["mlstm"] = 2 * d * dm + 3 * dm * dm + dm * d
        per["slstm"] = 8 * d * d // max(1, 1) + 2 * d * ff if ff else 8 * d * d
        total = sum(per[b] for b in self.pattern) * self.n_groups
        total += v * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k of num_experts)."""
        if not self.moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp_mult = 3 if self.act == "swiglu" else 2
        inactive = (self.moe.num_experts - self.moe.top_k) * mlp_mult * d * ff
        return self.param_count() - inactive * self.n_layers


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "llama32_vision_90b", "qwen2_1_5b", "qwen3_0_6b", "glm4_9b",
    "nemotron4_15b", "mixtral_8x7b", "mixtral_8x22b", "hymba_1_5b",
    "xlstm_350m", "musicgen_medium",
    # paper's own subjects (proxy configs, see DESIGN.md §8)
    "pangu_1b", "pangu_7b",
)

_ALIASES = {
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "qwen2-1.5b": "qwen2_1_5b",
    "qwen3-0.6b": "qwen3_0_6b",
    "glm4-9b": "glm4_9b",
    "nemotron-4-15b": "nemotron4_15b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "hymba-1.5b": "hymba_1_5b",
    "xlstm-350m": "xlstm_350m",
    "musicgen-medium": "musicgen_medium",
    "pangu-1b": "pangu_1b",
    "pangu-7b": "pangu_7b",
}


def get_arch(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def reduced(cfg: ArchConfig, groups: int = 1) -> ArchConfig:
    """CPU-smoke-test-sized member of the same family (same pattern/topology,
    tiny widths). Used by per-arch smoke tests; full configs are exercised
    only via the AOT dry-run."""
    p = len(cfg.pattern)
    nh = 4
    nkv = max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads < cfg.n_heads else nh
    return dataclasses.replace(
        cfg,
        n_layers=p * groups,
        d_model=128,
        n_heads=nh,
        n_kv_heads=nkv,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        ssm_chunk=16,
        n_ctx_tokens=min(cfg.n_ctx_tokens, 16) if cfg.n_ctx_tokens else 0,
        moe=dataclasses.replace(cfg.moe, num_experts=4, capacity_factor=2.0)
        if cfg.moe else None,
    )

"""Assigned input-shape set (all LM-family archs share these four cells).

  train_4k     seq 4,096   x global_batch 256   -> train_step
  prefill_32k  seq 32,768  x global_batch 32    -> prefill
  decode_32k   seq 32,768  x global_batch 128   -> serve_step (1 new token,
                                                  KV cache of seq_len)
  long_500k    seq 524,288 x global_batch 1     -> serve_step; requires a
               sub-quadratic arch (SWA rolling cache / SSM / xLSTM); skipped
               for pure full-attention archs per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch cannot decode at "
                       "524k context (quadratic); per assignment rule")
    return True, ""


def all_cells(arch_ids, get_arch):
    """Yield (arch_id, shape_name, supported, reason)."""
    for a in arch_ids:
        cfg = get_arch(a)
        for sname, spec in SHAPES.items():
            ok, why = cell_supported(cfg, spec)
            yield a, sname, ok, why

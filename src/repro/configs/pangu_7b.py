"""Assigned architecture config: openPangu-Embedded-7B (paper subject, proxy)

Proxy config for the paper's 7B subject. [arXiv:2505.22375 class; proxy]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="pangu_7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab=153376,
    rope_theta=10000.0,
    source="arXiv:2505.22375 class; proxy",
)

"""Assigned architecture config: xlstm-350m [ssm]

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304; alternating sLSTM +
mLSTM blocks (internal projections, no separate FFN).
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="xlstm_350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "slstm"),
    xlstm_proj=2,
    source="arXiv:2405.04517; unverified",
)

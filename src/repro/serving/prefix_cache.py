"""Content-addressed prefix cache over the paged KV pool (vLLM-style).

The paper's CoT serving workloads repeat long prompt prefixes across
requests (few-shot HumanEval/MBPP prompts, slow_think/auto_think system
preambles), so prefill over a shared prefix is recomputed work. This module
makes previously-computed prompt pages addressable by content:

  * `page_hashes` hashes each *full* page of prompt token ids with a
    chained SHA-256 — page i's hash covers page i-1's hash plus page i's
    tokens, so a hash pins both the tokens and their absolute position
    window (two prompts only share page i if they agree on every token up
    through page i).
  * `PrefixCache` maps hash -> physical page. On admission the scheduler
    walks a prompt's page hashes and maps the longest cached prefix
    straight into the request's page table (refcount +1 per hit via
    `acquire`), scheduling chunked prefill only for the uncached tail.
    Sharing is safe because only full, immutable pages are cached: the
    tail — including the prompt's last partial page — is always private,
    so copy-on-write is never needed mid-page, and a hit is bit-exact with
    recomputation (page content is a deterministic function of the prefix
    tokens under page-aligned chunking; int8 pools carry their
    per-(page, head) scales with the page).
  * When a cached page's last holder releases it (`PageAllocator`'s
    `reclaim_hook`), the page *parks* in an LRU instead of the free list —
    a second-chance free list. `evict` pops cold parked pages back to the
    allocator when a fresh allocation would otherwise fail; the scheduler
    only preempts (newest-yields) after the LRU is dry.
  * Promotion happens when a request *finishes*: `insert` publishes its
    full prompt pages (decode writes land strictly after them, so they are
    immutable by then). A hash already cached keeps its original page; the
    duplicate physical copy is freed normally.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Sequence

import numpy as np

from repro.serving.kv_pool import PageAllocator


def page_hashes(tokens: Sequence[int], page_size: int) -> List[bytes]:
    """Chained content hash per *full* page of `tokens`:
    h_i = H(h_{i-1} || tokens[i*page : (i+1)*page]). Partial trailing
    pages are never hashed (they are never shared)."""
    out: List[bytes] = []
    h = b""
    for i in range(len(tokens) // page_size):
        window = tokens[i * page_size:(i + 1) * page_size]
        m = hashlib.sha256(h)
        m.update(np.asarray(window, np.int32).tobytes())
        h = m.digest()
        out.append(h)
    return out


class PrefixCache:
    """hash -> physical page map + LRU of unreferenced cached pages.

    Installs itself as `alloc.reclaim_hook`; all refcount transitions stay
    inside `PageAllocator` — this class only decides whether a
    zero-refcount page parks (cached) or frees (uncached), and in which
    order parked pages are evicted."""

    def __init__(self, alloc: PageAllocator):
        self.alloc = alloc
        self._by_hash: Dict[bytes, int] = {}
        self._by_page: Dict[int, bytes] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self.n_evicted = 0
        alloc.reclaim_hook = self._park

    # -- allocator hook ------------------------------------------------------

    def _park(self, page: int) -> bool:
        """Claim a page whose refcount just hit 0 iff it is cached; parked
        pages queue at the MRU end (they were referenced until now)."""
        if page not in self._by_page:
            return False
        self._lru[page] = None
        self._lru.move_to_end(page)
        return True

    # -- introspection -------------------------------------------------------

    @property
    def n_cached(self) -> int:
        return len(self._by_page)

    @property
    def n_unreferenced(self) -> int:
        return len(self._lru)

    # -- admission -----------------------------------------------------------

    def lookup(self, hashes: Sequence[bytes]) -> List[int]:
        """Physical pages of the longest cached prefix of `hashes`
        (consecutive from page 0; a gap ends the run)."""
        out: List[int] = []
        for h in hashes:
            page = self._by_hash.get(h)
            if page is None:
                break
            out.append(page)
        return out

    def acquire(self, pages: Sequence[int]) -> None:
        """Reference hit pages for a new holder: parked pages leave the LRU
        (adopt), live ones gain a refcount."""
        for p in pages:
            p = int(p)
            if p in self._lru:
                del self._lru[p]
                self.alloc.adopt(p)
            else:
                self.alloc.incref(p)

    # -- promotion -----------------------------------------------------------

    def insert(self, hashes: Sequence[bytes], pages: Sequence[int]) -> int:
        """Publish a finished request's full prompt pages. First writer
        wins: a hash that is already cached keeps its page (the duplicate
        copy frees normally). Returns how many pages became cached."""
        assert len(hashes) == len(pages), (len(hashes), len(pages))
        n = 0
        for h, p in zip(hashes, pages):
            p = int(p)
            if h in self._by_hash:
                continue
            assert p not in self._by_page, \
                f"page {p} already caches different content"
            self._by_hash[h] = p
            self._by_page[p] = h
            n += 1
        return n

    # -- eviction ------------------------------------------------------------

    def evict(self, n: int) -> int:
        """Evict up to n cold parked pages (LRU first) back to the free
        list, dropping their hash entries. Returns how many were freed."""
        freed = 0
        while freed < n and self._lru:
            page, _ = self._lru.popitem(last=False)
            del self._by_hash[self._by_page.pop(page)]
            self.alloc.reclaim(page)
            self.n_evicted += 1
            freed += 1
        return freed

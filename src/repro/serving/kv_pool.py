"""Paged KV cache: a fixed pool of fixed-size pages with per-page int8 or
packed-int4 quantization (per-head scales) and free-list reuse.

Layout per transformer block (leading scan-group axis G added by
`transformer.init_paged_pools`):

    k, v   : (P, page_size, n_kv_heads, head_dim)      int8 | cache dtype
             (P, page_size, n_kv_heads, head_dim // 2) uint8, kv_bits=4:
             two nibbles per byte along head_dim in the grouped-halves
             layout (`qtypes.pack_int4_halves_lastdim`)
    k_s,v_s: (P, n_kv_heads) float32                   (quantized pools)

The leaf dtype is the discriminator — uint8 means packed int4, int8 means
int8, floats mean an unquantized pool — so kernels and oracles that only
see bare arrays can pick the right read path without any config plumbing.

Physical page 0 is reserved as the *scratch page*: unassigned page-table
entries point at it, so every gather/scatter stays shape-static and
branch-free — writes to it are garbage sinks, reads from it are masked by
`kv_lengths`. The host-side `PageAllocator` hands out pages 1..P-1.

Quantization is per (page, kv-head): one f32 scale covers page_size tokens,
so the scale overhead amortizes to 4/page_size bytes per token per head —
the int8 pool lands at ~50% of the bf16 pool's bytes/token instead of the
~56% a per-token-scale layout costs at small head_dim. Decode writes land
one token at a time: the target page is gathered, dequantized, masked to
the tokens actually written so far (freed pages are reused without
zeroing), extended, and requantized against the updated per-head absmax.
That re-rounding is bounded by the final page scale and touches only
page_size tokens per step — O(page) work against the attention's O(T).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant.qtypes import (pack_int4_halves_lastdim, paper_scale,
                                     qmax, qmin, unpack_int4_halves_lastdim)

SCRATCH_PAGE = 0


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------

class PageAllocator:
    """Refcounted free-list over physical pages 1..n_pages-1 (page 0 is
    scratch). Pages allocate at refcount 1; `incref` shares a page across
    page tables (prefix-cache hits), and `free` decrements one holder —
    the page returns to the free list only when its last holder releases.

    `free` keeps the hardening against the two scheduler bugs that silently
    corrupt a shared pool: double-free (a zero-refcount page re-enters the
    free list while a sequence still maps it -> cross-sequence KV leakage)
    and out-of-range ids (a stale page table row scattering into foreign
    memory).

    A page dropping to refcount 0 is offered to `reclaim_hook` (set by the
    prefix cache): if the hook claims it, the page is *parked* — neither
    live nor allocatable — until `adopt` re-references it (a cache hit on a
    cold page) or `reclaim` returns it to the free list (cache eviction).
    """

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "need at least one allocatable page + scratch"
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._ref: Dict[int, int] = {}
        self._parked: set = set()
        self.reclaim_hook: Optional[Callable[[int], bool]] = None

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._ref)

    @property
    def n_parked(self) -> int:
        return len(self._parked)

    def refcount(self, page: int) -> int:
        return self._ref.get(int(page), 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages at refcount 1 or None (all-or-nothing; no partials)."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._ref[p] = 1
        return out

    def incref(self, page: int) -> None:
        """Add a holder to a live page (sharing an existing mapping)."""
        p = int(page)
        assert p in self._ref, f"incref of unallocated page {p}"
        self._ref[p] += 1

    def free(self, pages) -> None:
        """Release one holder per listed page."""
        for p in pages:
            p = int(p)
            assert p != SCRATCH_PAGE, "freeing the scratch page"
            assert 0 < p < self.n_pages, f"page id {p} out of range " \
                f"[1, {self.n_pages - 1}]"
            assert p in self._ref, f"double free of page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                if self.reclaim_hook is not None and self.reclaim_hook(p):
                    self._parked.add(p)
                else:
                    self._free.append(p)

    def adopt(self, page: int) -> None:
        """Re-reference a parked page (prefix-cache hit on a cold page)."""
        p = int(page)
        assert p in self._parked, f"adopt of unparked page {p}"
        self._parked.discard(p)
        self._ref[p] = 1

    def reclaim(self, page: int) -> None:
        """Return a parked page to the free list (prefix-cache eviction)."""
        p = int(page)
        assert p in self._parked, f"reclaim of unparked page {p}"
        self._parked.discard(p)
        self._free.append(p)


# ---------------------------------------------------------------------------
# Device-side pool (single block, no G axis; callers vmap/scan over G)
# ---------------------------------------------------------------------------

def init_pool(cfg, n_pages: int, page_size: int, kv_bits: int = 16,
              dtype=jnp.bfloat16) -> dict:
    assert kv_bits in (16, 8, 4), f"unsupported kv_bits {kv_bits}"
    nkv, hd = cfg.n_kv_heads, cfg.hd
    shape = (n_pages, page_size, nkv, hd)
    scales = {"k_s": jnp.zeros((n_pages, nkv), jnp.float32),
              "v_s": jnp.zeros((n_pages, nkv), jnp.float32)}
    if kv_bits == 8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8), **scales}
    if kv_bits == 4:
        assert hd % 2 == 0, f"head_dim {hd} must be even for packed int4"
        pshape = (n_pages, page_size, nkv, hd // 2)
        return {"k": jnp.zeros(pshape, jnp.uint8),
                "v": jnp.zeros(pshape, jnp.uint8), **scales}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def pool_is_quantized(pool: dict) -> bool:
    return pool["k"].dtype in (jnp.int8, jnp.uint8)


def pool_kv_bits(pool: dict) -> int:
    """Recover kv_bits from the leaf dtype (uint8 = packed int4)."""
    dt = pool["k"].dtype
    if dt == jnp.uint8:
        return 4
    return 8 if dt == jnp.int8 else 16


def pool_bytes(pool: dict) -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.tree.leaves(pool))


def bytes_per_token(pool: dict) -> float:
    """Pool bytes per token *slot* (both K and V, incl. scale overhead)."""
    n_pages, page = pool["k"].shape[0], pool["k"].shape[1]
    return pool_bytes(pool) / (n_pages * page)


def _quantize_pages(x: jax.Array, bits: int = 8):
    """x: (..., page, nkv, hd) -> (quantized pages, per (page, head) scale).

    bits=8 yields int8 codes; bits=4 narrow-clips to [-7, 7] and packs two
    nibbles per byte along head_dim (uint8, grouped halves) so no dense
    intermediate wider than the packed page ever lands in the pool.
    """
    am = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-3, -1))   # (..., nkv)
    s = paper_scale(am, bits)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None, :, None]),
                 qmin(bits), qmax(bits)).astype(jnp.int8)
    if bits == 4:
        return pack_int4_halves_lastdim(q), s
    return q, s


def _unpack_gathered(pages: jax.Array) -> jax.Array:
    """Undo nibble packing on gathered pages (uint8 leaves only); int8 and
    float pages pass through untouched."""
    if pages.dtype == jnp.uint8:
        return unpack_int4_halves_lastdim(pages)
    return pages


# -- prefill: bulk page fill -------------------------------------------------

def write_prefill(pool: dict, k: jax.Array, v: jax.Array,
                  page_rows: jax.Array, lengths: jax.Array) -> dict:
    """Scatter a prompt's K/V into its pages.

    k, v: (B, S, nkv, hd) with S % page_size == 0 (prompt bucket);
    page_rows: (B, S // page_size) physical ids (scratch-padded);
    lengths: (B,) valid prompt tokens — positions beyond are zeroed so they
    can't inflate the page scale.
    """
    page = pool["k"].shape[1]
    b, s, nkv, hd = k.shape
    assert s % page == 0, (s, page)
    valid = (jnp.arange(s)[None, :] < lengths[:, None])[..., None, None]
    kz = jnp.where(valid, k, 0).reshape(b, s // page, page, nkv, hd)
    vz = jnp.where(valid, v, 0).reshape(b, s // page, page, nkv, hd)
    ids = page_rows.reshape(-1)
    pool = dict(pool)
    if pool_is_quantized(pool):
        bits = pool_kv_bits(pool)
        kq, ks = _quantize_pages(kz, bits)
        vq, vs = _quantize_pages(vz, bits)
        # kq/vq last dim is hd//2 for packed int4 — reshape shape-generically
        pool["k"] = pool["k"].at[ids].set(kq.reshape(-1, *kq.shape[2:]))
        pool["v"] = pool["v"].at[ids].set(vq.reshape(-1, *vq.shape[2:]))
        pool["k_s"] = pool["k_s"].at[ids].set(ks.reshape(-1, nkv))
        pool["v_s"] = pool["v_s"].at[ids].set(vs.reshape(-1, nkv))
    else:
        dt = pool["k"].dtype
        pool["k"] = pool["k"].at[ids].set(
            kz.reshape(-1, page, nkv, hd).astype(dt))
        pool["v"] = pool["v"].at[ids].set(
            vz.reshape(-1, page, nkv, hd).astype(dt))
    return pool


# -- chunked prefill: fused quantize-on-write ---------------------------------

def chunk_window_pages(chunk_tokens: int, page_size: int) -> int:
    """Pages a C-token write window can span at arbitrary (unaligned) start:
    C//page full pages plus one boundary page."""
    assert chunk_tokens % page_size == 0, (chunk_tokens, page_size)
    return chunk_tokens // page_size + 1


def write_chunk(pool: dict, k: jax.Array, v: jax.Array,
                window_rows: jax.Array, start: jax.Array,
                n_new: jax.Array, src: Optional[dict] = None) -> dict:
    """Write up to C new tokens per sequence at positions start..start+n_new-1,
    quantizing directly into pages (no dense intermediate cache).

    k, v: (B, C, nkv, hd) chunk K/V (positions beyond n_new are garbage);
    window_rows: (B, Wc) physical page ids covering page indices
    start//page .. start//page + Wc - 1 (scratch beyond the sequence's
    allocation), Wc = chunk_window_pages(C, page);
    start: (B,) absolute position of chunk token 0 (== tokens already in
    cache); n_new: (B,) valid tokens this step — C for a full prefill chunk,
    1 for a riding decode slot, 0 for an idle slot.

    Boundary pages are gathered, dequantized, masked to their previously
    written tokens (positions < start; freed pages are reused without
    zeroing), merged with the chunk, and requantized per (page, head) —
    the same bounded re-rounding `write_token` pays, amortized over the
    whole chunk. Unwritten window positions are zeroed so they cannot
    inflate the page scale.

    src: optional pre-gathered window leaves {leaf: (B, Wc, ...)} to merge
    against instead of gathering pool[leaf][window_rows] — `truncate` uses
    this to rebuild the window from its pre-speculation snapshot without a
    restore scatter + re-gather round trip.
    """
    page = pool["k"].shape[1]
    b, c, nkv, hd = k.shape
    wc = window_rows.shape[1]
    assert wc * page >= c + page, (wc, page, c)
    wpos = jnp.arange(wc * page)[None, :]                     # window-local
    base = (start // page) * page
    gpos = base[:, None] + wpos                               # absolute
    off = start - base                                        # (B,)
    j = wpos - off[:, None]                                   # chunk index
    jc = jnp.clip(j, 0, c - 1)
    keep_old = (gpos < start[:, None])[..., None, None]
    use_new = ((j >= 0) & (j < n_new[:, None]))[..., None, None]
    ids = window_rows.reshape(-1)
    quantized = pool_is_quantized(pool)
    bits = pool_kv_bits(pool)
    pool = dict(pool)
    for name, s_name, tok in (("k", "k_s", k), ("v", "v_s", v)):
        gathered = (src[name] if src is not None
                    else pool[name][window_rows])
        pages = _unpack_gathered(gathered).astype(jnp.float32)  # (B,Wc,p,..)
        if quantized:
            sc = (src[s_name] if src is not None
                  else pool[s_name][window_rows])             # (B, Wc, nkv)
            pages = pages * sc[:, :, None, :, None]
        f = pages.reshape(b, wc * page, nkv, hd)
        f = jnp.where(keep_old, f, 0.0)
        newv = jnp.take_along_axis(tok.astype(jnp.float32),
                                   jc[:, :, None, None], axis=1)
        f = jnp.where(use_new, newv, f)
        f = f.reshape(b, wc, page, nkv, hd)
        if quantized:
            q, s = _quantize_pages(f, bits)
            pool[name] = pool[name].at[ids].set(
                q.reshape(-1, *q.shape[2:]))
            pool[s_name] = pool[s_name].at[ids].set(s.reshape(-1, nkv))
        else:
            pool[name] = pool[name].at[ids].set(
                f.reshape(-1, page, nkv, hd).astype(pool[name].dtype))
    return pool


# -- speculative verify: page-exact rollback ---------------------------------

def verify_window_pages(chunk_tokens: int, page_size: int) -> int:
    """Pages a C-token verify window can span at arbitrary start. Unlike
    `chunk_window_pages` the window length (k+1 draft tokens) need not be
    page-aligned, so this is ceil(C/page) full-or-partial pages plus one
    boundary page — sized to satisfy write_chunk's Wc*page >= C + page."""
    return -(-chunk_tokens // page_size) + 1


def truncate(pool: dict, window_rows: jax.Array, snap: dict, k: jax.Array,
             v: jax.Array, start: jax.Array, n_keep: jax.Array) -> dict:
    """Roll a speculative window back page-exactly, keeping only the
    accepted prefix.

    `snap` holds the window pages as they were *before* the verify write
    (one leaf per pool leaf, shaped (B, Wc, ...) — a `pool[leaf][window_rows]`
    gather). Re-running `write_chunk` against the snapshot (src=snap, so
    the post-verify page contents never enter the merge) with
    n_keep <= n_new makes the final pool bit-identical to having written
    only the accepted tokens in the first place: rewriting on top of the
    post-verify pages instead would pay an extra dequant-requant round
    trip on the boundary page and drift from the vanilla chain.

    n_keep: (B,) tokens to commit (accepted + bonus; 0 for idle lanes).
    """
    return write_chunk(pool, k, v, window_rows, start, n_keep, src=snap)


# -- decode: one token per sequence ------------------------------------------

def _requant_page(pages_f, new_tok, slot, bits=8):
    """pages_f: (B, page, nkv, hd) f32 (already dequantized + masked);
    new_tok: (B, nkv, hd); slot: (B,) write slot. Returns (q, scale)."""
    b = pages_f.shape[0]
    pages_f = pages_f.at[jnp.arange(b), slot].set(
        new_tok.astype(jnp.float32))
    return _quantize_pages(pages_f, bits)


def write_token(pool: dict, page_table: jax.Array, pos: jax.Array,
                k: jax.Array, v: jax.Array) -> dict:
    """Write one token per sequence at absolute position `pos` (B,).

    page_table: (B, W) physical ids; k, v: (B, nkv, hd). Inactive slots
    should carry pos=0 with a scratch-zeroed page-table row.
    """
    page = pool["k"].shape[1]
    b = k.shape[0]
    pidx = pos // page
    slot = pos % page
    phys = page_table[jnp.arange(b), pidx]                      # (B,)
    pool = dict(pool)
    if pool_is_quantized(pool):
        # Gather page, dequantize, zero not-yet-written slots (pages are
        # reused without zeroing), extend, requantize per (page, head).
        bits = pool_kv_bits(pool)
        live = jnp.arange(page)[None, :, None, None] <= slot[:, None, None,
                                                            None]
        for name, s_name, tok in (("k", "k_s", k), ("v", "v_s", v)):
            pg = _unpack_gathered(pool[name][phys]).astype(
                jnp.float32)                                    # (B,page,..)
            sc = pool[s_name][phys]                             # (B,nkv)
            pg = jnp.where(live, pg * sc[:, None, :, None], 0.0)
            q, s_new = _requant_page(pg, tok, slot, bits)
            pool[name] = pool[name].at[phys].set(q)
            pool[s_name] = pool[s_name].at[phys].set(s_new)
    else:
        dt = pool["k"].dtype
        idx = (phys, slot)
        pool["k"] = pool["k"].at[idx].set(k.astype(dt))
        pool["v"] = pool["v"].at[idx].set(v.astype(dt))
    return pool


# -- reads -------------------------------------------------------------------

def gather_kv(pool: dict, page_table: jax.Array):
    """Dequantized gather: (B, W*page, nkv, hd) bf16 pair — the XLA
    reference read path (the Pallas kernel streams pages instead)."""
    page = pool["k"].shape[1]
    b, w = page_table.shape
    out = []
    for name, s_name in (("k", "k_s"), ("v", "v_s")):
        pages = _unpack_gathered(pool[name][page_table])  # (B,W,page,nkv,hd)
        if pool_is_quantized(pool):
            sc = pool[s_name][page_table]               # (B, W, nkv)
            pages = pages.astype(jnp.float32) * sc[:, :, None, :, None]
        out.append(pages.reshape(b, w * page, *pages.shape[3:])
                   .astype(jnp.bfloat16))
    return out[0], out[1]

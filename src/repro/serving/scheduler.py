"""Continuous-batching scheduler over the paged KV pool.

Host-side control plane: requests wait in a FIFO, get admitted into one of
`n_slots` fixed batch slots when a slot and enough pages are free, and
release everything on completion. Two admission regimes share the slot/page
machinery:

  * legacy (per-admission prefill): a request needs all its prompt pages up
    front; `lengths`/`prefill_progress` jump straight to the prompt length.
  * chunked prefill: a request is admitted with only its *first chunk's*
    pages; `prefill_progress[slot]` tracks how many prompt tokens have been
    written, pages are granted chunk-by-chunk via `grow_to`, and the engine
    batches chunks from several slots with ongoing decode slots into one
    mixed step under a token budget.

Decode capacity is ensured every step: a sequence crossing a page boundary
gets a fresh page from the free list; when the pool is exhausted, cold
unreferenced prefix-cache pages are evicted first (LRU — the second-chance
free list), and only then is the most-recently-admitted active request
preempted (recompute-style: its pages are released — including a
partially-prefilled prompt's — and it requeues at the front of the FIFO
with its progress reset, generation restarting from the prompt: the
vLLM-style answer to fragmentation-free oversubscription).

With `prefix_cache=True` (chunked admission only), admission walks the
prompt's chained page hashes (serving/prefix_cache.py) and maps the longest
cached prefix of *full* pages straight into the request's page table
(refcount +1 per shared page); `lengths`/`prefill_progress` start at the
hit length and only the uncached tail is chunk-prefilled. At least one
prompt token is always recomputed so the last-token logits exist. Finished
requests promote their full prompt pages into the cache in `complete`.
Releasing a slot — completion or preemption — only ever *decrements*
refcounts through the single `_release` choke point: a shared page stays
mapped for its other holders, and a cached page whose last holder leaves
parks in the cache LRU instead of the free list.

The device never sees any of this: it gets a dense (n_slots, W) page table,
per-slot lengths, and last tokens. Inactive slots carry length 0 and a
scratch-zeroed page-table row, so their (masked, unused) lanes stay
shape-static in the jitted steps.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_pool import PageAllocator, SCRATCH_PAGE
from repro.serving.prefix_cache import PrefixCache, page_hashes


@dataclasses.dataclass
class Request:
    """One in-flight request. The stop condition (budget + eos) is owned by
    the engine as a cot.StopPolicy; budget here is bookkeeping only."""
    rid: int
    prompt: List[int]               # directive token already appended
    mode: str
    budget: int
    out: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0


class PagedScheduler:
    def __init__(self, *, n_slots: int, n_pages: int, page_size: int,
                 max_pages_per_seq: int, prefix_cache: bool = False):
        self.n_slots = n_slots
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        self.alloc = PageAllocator(n_pages)
        self.cache: Optional[PrefixCache] = \
            PrefixCache(self.alloc) if prefix_cache else None
        self.page_table = np.full((n_slots, max_pages_per_seq), SCRATCH_PAGE,
                                  np.int32)
        self.lengths = np.zeros(n_slots, np.int32)      # tokens in cache
        self.prefill_progress = np.zeros(n_slots, np.int32)  # prompt written
        self.seq_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self.active: Dict[int, Request] = {}
        self.waiting: Deque[Request] = deque()
        self.free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self._admit_order: Dict[int, int] = {}          # slot -> seqno
        self._admit_seq = 0
        self._hashes: Dict[int, List[bytes]] = {}       # slot -> page hashes
        self.n_evictions = 0
        self.prefix_hit_tokens = 0       # prompt tokens served from cache
        self.prefix_prompt_tokens = 0    # prompt tokens through admission

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        need = -(-len(req.prompt) // self.page_size)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"prompt needs {need} pages > max_pages_per_seq "
                f"{self.max_pages_per_seq}")
        self.waiting.append(req)

    @property
    def idle(self) -> bool:
        return not self.active and not self.waiting

    # -- allocation ----------------------------------------------------------

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """All-or-nothing alloc with second-chance eviction: when the free
        list is short, cold unreferenced prefix-cache pages are evicted
        (LRU first) before giving up — callers preempt only after this
        returns None."""
        pages = self.alloc.alloc(n)
        if pages is None and self.cache is not None:
            self.cache.evict(n - self.alloc.n_free)
            pages = self.alloc.alloc(n)
        return pages

    # -- admission -----------------------------------------------------------

    def admit(self, max_prefill_pages: Optional[int] = None
              ) -> List[Tuple[int, Request]]:
        """Admit FIFO-head requests while a slot + enough pages are free.

        max_prefill_pages=None (legacy per-admission prefill): a request
        needs all its prompt pages up front and enters fully prefilled
        (the caller runs the one-shot prefill right after). The prefix
        cache is bypassed — the one-shot prefill would rewrite shared
        pages.

        max_prefill_pages=k (chunked prefill): the longest cached prefix of
        full prompt pages (if any) maps directly into the page table with a
        refcount each, then the request needs only its first uncached
        chunk's pages — min(tail pages, k) — and enters with
        prefill_progress at the hit length; later chunks grow the page list
        via grow_to. At least one prompt token is always left to recompute
        so the mixed step produces last-token logits."""
        admitted = []
        use_cache = self.cache is not None and max_prefill_pages is not None
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            total = -(-len(req.prompt) // self.page_size)
            hashes: List[bytes] = []
            hits: List[int] = []
            if use_cache:
                hashes = page_hashes(req.prompt, self.page_size)
                hits = self.cache.lookup(hashes)
                if len(hits) * self.page_size >= len(req.prompt):
                    hits = hits[:-1]
            n_hit = len(hits)
            need = total - n_hit
            if max_prefill_pages is not None:
                need = min(need, max_prefill_pages)
            if hits:
                # reference the hits before allocating the tail, so tail
                # eviction can never reclaim them out from under us
                self.cache.acquire(hits)
            pages = self._alloc_pages(need)
            if pages is None:
                if hits:
                    self.alloc.free(hits)       # back to live/LRU state
                break
            self.waiting.popleft()
            slot = self.free_slots.pop()
            self.seq_pages[slot] = hits + pages
            self.page_table[slot, :] = SCRATCH_PAGE
            self.page_table[slot, :n_hit + need] = hits + pages
            hit_tokens = n_hit * self.page_size
            if max_prefill_pages is None:
                self.lengths[slot] = len(req.prompt)
                self.prefill_progress[slot] = len(req.prompt)
            else:
                self.lengths[slot] = hit_tokens
                self.prefill_progress[slot] = hit_tokens
            if use_cache:
                self._hashes[slot] = hashes
                self.prefix_hit_tokens += hit_tokens
                self.prefix_prompt_tokens += len(req.prompt)
            self.active[slot] = req
            self._admit_order[slot] = self._admit_seq
            self._admit_seq += 1
            admitted.append((slot, req))
        return admitted

    # -- slot phases (chunked prefill) ----------------------------------------

    def prefilling_slots(self) -> List[int]:
        """Active slots whose prompt is not fully written yet, in admission
        order (FIFO fairness for chunk scheduling)."""
        slots = [s for s in self.active
                 if self.prefill_progress[s] < len(self.active[s].prompt)]
        return sorted(slots, key=lambda s: self._admit_order[s])

    def decoding_slots(self) -> List[int]:
        return sorted(s for s in self.active
                      if self.prefill_progress[s] >= len(self.active[s].prompt))

    # -- page capacity --------------------------------------------------------

    def grow_to(self, slot: int, n_tokens: int) -> List[Request]:
        """Grow `slot`'s page list to cover `n_tokens` cache positions,
        evicting cold prefix-cache pages and then preempting the
        most-recently-admitted active request when the pool is dry —
        *including the grower itself*: a newest slot that can't
        grow yields (self-preempts) rather than starving older work, so the
        oldest request always makes monotonic progress and mutual-eviction
        livelock is impossible. Returns the preempted (requeued) requests —
        the caller must re-derive any slot sets it holds (and check the
        grower survived), since victims may be mid-prefill: their pages,
        including partially-written prompt pages, are freed and their
        progress reset (preemption-safe partial-prefill release)."""
        need_pages = -(-n_tokens // self.page_size)
        if need_pages > self.max_pages_per_seq:
            raise RuntimeError(
                f"sequence in slot {slot} exceeded max_pages_per_seq")
        evicted = []
        while need_pages > len(self.seq_pages[slot]):
            page = self._alloc_pages(1)
            if page is None:
                if len(self.active) <= 1:
                    raise RuntimeError(
                        "KV pool too small for a single sequence")
                victim = max(self.active, key=lambda s: self._admit_order[s])
                evicted.append(self._preempt(victim))
                if victim == slot:
                    return evicted
                continue
            pidx = len(self.seq_pages[slot])
            self.seq_pages[slot].append(page[0])
            self.page_table[slot, pidx] = page[0]
        return evicted

    def truncate_to(self, slot: int, n_tokens: int) -> None:
        """Shrink `slot`'s page list to exactly cover `n_tokens` cache
        positions, returning surplus pages grown for a speculative window
        whose tail was rejected. Surplus pages release through the same
        `_return_pages` choke point as preemption, so prefix-cache parked
        pages and refcounts stay consistent; shared prefix-hit pages are
        never surplus (the kept prefix always spans at least the prompt's
        cached pages — the engine only truncates back to a length >= the
        pre-speculation committed length)."""
        need = -(-n_tokens // self.page_size)
        pages = self.seq_pages[slot]
        if len(pages) <= need:
            return
        surplus = pages[need:]
        self.seq_pages[slot] = pages[:need]
        self.page_table[slot, need:len(pages)] = SCRATCH_PAGE
        self._return_pages(surplus)

    def ensure_decode_capacity(self) -> List[Request]:
        """Each active decode slot writes position lengths[slot] this step;
        grow its page list across page boundaries, preempting if the pool
        is dry. Returns the preempted (requeued) requests."""
        evicted = []
        for slot in sorted(list(self.active)):
            if slot not in self.active:        # evicted by an earlier slot
                continue
            evicted.extend(self.grow_to(slot, int(self.lengths[slot]) + 1))
        return evicted

    def _return_pages(self, pages: List[int]) -> None:
        """THE page-release choke point: every refcount decrement the
        scheduler performs funnels through here (completion and preemption
        both route via `_release`). A shared page only loses this holder;
        a cached page whose last holder leaves parks in the prefix-cache
        LRU instead of the free list."""
        self.alloc.free(pages)

    def _release(self, slot: int) -> Request:
        req = self.active.pop(slot)
        self._return_pages(self.seq_pages[slot])
        self.seq_pages[slot] = []
        self.page_table[slot, :] = SCRATCH_PAGE
        self.lengths[slot] = 0
        self.prefill_progress[slot] = 0
        self._admit_order.pop(slot, None)
        self._hashes.pop(slot, None)
        self.free_slots.append(slot)
        return req

    def _preempt(self, slot: int) -> Request:
        req = self._release(slot)
        req.out = []                 # recompute preemption: restart cleanly
        req.preemptions += 1
        self.n_evictions += 1
        self.waiting.appendleft(req)
        return req

    # -- completion ----------------------------------------------------------

    def complete(self, slot: int) -> Request:
        """Finish a request: promote its *full* prompt pages into the
        prefix cache (immutable by now — the partial tail page and decode
        writes land strictly after them), then release the slot."""
        if self.cache is not None:
            req = self.active[slot]
            n_full = len(req.prompt) // self.page_size
            hashes = self._hashes.get(slot)
            if hashes is None:
                hashes = page_hashes(req.prompt, self.page_size)
            self.cache.insert(hashes[:n_full],
                              self.seq_pages[slot][:n_full])
        return self._release(slot)

"""Continuous-batching scheduler over the paged KV pool.

Host-side control plane: requests wait in a FIFO, get admitted into one of
`n_slots` fixed batch slots when a slot and enough pages for their prompt
are free, and release everything on completion. Decode capacity is ensured
every step: a sequence crossing a page boundary gets a fresh page from the
free list; when the pool is exhausted the most-recently-admitted other
request is preempted (recompute-style: its pages are freed and it requeues
at the front of the FIFO, generation restarting from the prompt — the
vLLM-style answer to fragmentation-free oversubscription).

The device never sees any of this: it gets a dense (n_slots, W) page table,
per-slot lengths, and last tokens. Inactive slots carry length 0 and a
scratch-zeroed page-table row, so their (masked, unused) lanes stay
shape-static in the jitted decode step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_pool import PageAllocator, SCRATCH_PAGE


@dataclasses.dataclass
class Request:
    """One in-flight request. The stop condition (budget + eos) is owned by
    the engine as a cot.StopPolicy; budget here is bookkeeping only."""
    rid: int
    prompt: List[int]               # directive token already appended
    mode: str
    budget: int
    out: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0


class PagedScheduler:
    def __init__(self, *, n_slots: int, n_pages: int, page_size: int,
                 max_pages_per_seq: int):
        self.n_slots = n_slots
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        self.alloc = PageAllocator(n_pages)
        self.page_table = np.full((n_slots, max_pages_per_seq), SCRATCH_PAGE,
                                  np.int32)
        self.lengths = np.zeros(n_slots, np.int32)      # tokens in cache
        self.seq_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self.active: Dict[int, Request] = {}
        self.waiting: Deque[Request] = deque()
        self.free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self._admit_order: Dict[int, int] = {}          # slot -> seqno
        self._admit_seq = 0
        self.n_evictions = 0

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        need = -(-len(req.prompt) // self.page_size)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"prompt needs {need} pages > max_pages_per_seq "
                f"{self.max_pages_per_seq}")
        self.waiting.append(req)

    @property
    def idle(self) -> bool:
        return not self.active and not self.waiting

    # -- admission -----------------------------------------------------------

    def admit(self) -> List[Tuple[int, Request]]:
        """Admit FIFO-head requests while a slot + prompt pages are free."""
        admitted = []
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            need = -(-len(req.prompt) // self.page_size)
            pages = self.alloc.alloc(need)
            if pages is None:
                break
            self.waiting.popleft()
            slot = self.free_slots.pop()
            self.seq_pages[slot] = pages
            self.page_table[slot, :] = SCRATCH_PAGE
            self.page_table[slot, :need] = pages
            self.lengths[slot] = len(req.prompt)
            self.active[slot] = req
            self._admit_order[slot] = self._admit_seq
            self._admit_seq += 1
            admitted.append((slot, req))
        return admitted

    # -- decode capacity -----------------------------------------------------

    def ensure_decode_capacity(self) -> List[Request]:
        """Each active slot writes position lengths[slot] this step; grow its
        page list across page boundaries, preempting if the pool is dry.
        Returns the preempted (requeued) requests."""
        evicted = []
        for slot in sorted(list(self.active)):
            if slot not in self.active:        # evicted by an earlier slot
                continue
            pidx = int(self.lengths[slot]) // self.page_size
            if pidx >= self.max_pages_per_seq:
                raise RuntimeError(
                    f"sequence in slot {slot} exceeded max_pages_per_seq")
            while pidx >= len(self.seq_pages[slot]):
                page = self.alloc.alloc(1)
                if page is None:
                    victim = self._pick_victim(exclude=slot)
                    if victim is None:
                        raise RuntimeError(
                            "KV pool too small for a single sequence")
                    evicted.append(self._preempt(victim))
                    continue
                self.seq_pages[slot].append(page[0])
                self.page_table[slot, pidx] = page[0]
        return evicted

    def _pick_victim(self, exclude: int) -> Optional[int]:
        cands = [s for s in self.active if s != exclude]
        if not cands:
            return None
        return max(cands, key=lambda s: self._admit_order[s])

    def _release(self, slot: int) -> Request:
        req = self.active.pop(slot)
        self.alloc.free(self.seq_pages[slot])
        self.seq_pages[slot] = []
        self.page_table[slot, :] = SCRATCH_PAGE
        self.lengths[slot] = 0
        self._admit_order.pop(slot, None)
        self.free_slots.append(slot)
        return req

    def _preempt(self, slot: int) -> Request:
        req = self._release(slot)
        req.out = []                 # recompute preemption: restart cleanly
        req.preemptions += 1
        self.n_evictions += 1
        self.waiting.appendleft(req)
        return req

    # -- completion ----------------------------------------------------------

    def complete(self, slot: int) -> Request:
        return self._release(slot)

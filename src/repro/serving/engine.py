"""Batched serving engine: padded-prefill + decode loop with per-request
lengths, EOS early-exit, CoT mode policies, and quantized execution.

The engine drives the same `transformer.prefill` / `decode_step` functions
the dry-run lowers; jit caching keys on (arch, quant config, impl, batch
geometry). Continuous-batching-lite: requests are packed left-aligned into
fixed batch slots with a per-request `lengths` vector; decode steps advance
per-request positions independently, so heterogeneous prompt lengths share
one compiled step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.serving import cot, sampling


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]          # generated tokens per request
    modes: List[str]
    prompt_lens: List[int]
    steps_run: int


class ServingEngine:
    def __init__(self, params, cfg, *, qcfg=None, impl=None, kv_bits=16,
                 eos_id: Optional[int] = None, dtype=jnp.bfloat16):
        self.params = params
        self.cfg = cfg
        self.qcfg = qcfg
        self.impl = impl
        self.kv_bits = kv_bits
        self.eos_id = eos_id
        self.dtype = dtype
        self._prefill = jax.jit(
            partial(transformer.prefill, cfg=cfg, qcfg=qcfg, impl=impl,
                    kv_bits=kv_bits, dtype=dtype),
            static_argnames=("max_len",))
        self._decode = jax.jit(
            partial(transformer.decode_step, cfg=cfg, qcfg=qcfg, impl=impl,
                    dtype=dtype))

    # -- request packing ------------------------------------------------------

    def _pack(self, prompts: Sequence[Sequence[int]]):
        b = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        s = int(lens.max())
        toks = np.zeros((b, s), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return jnp.asarray(toks), jnp.asarray(lens)

    # -- generation -----------------------------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]], *, max_new: int = 32,
                 mode: str = "slow_think", sampler: str = "greedy",
                 seed: int = 0, ctx=None) -> GenerationResult:
        """Generate under a CoT mode. Directive token appended per paper §4.1;
        per-request budgets follow the mode policy (auto_think adapts)."""
        cfg = self.cfg
        prompts = [cot.apply_mode(p, mode, cfg.vocab) for p in prompts]
        budgets = np.array([cot.budget_for(mode, len(p), max_new)
                            for p in prompts], np.int32)
        toks, lens = self._pack(prompts)
        b, s = toks.shape
        max_len = s + int(budgets.max()) + 1

        batch = {"tokens": toks, "lengths": lens}
        if ctx is not None:
            batch["ctx"] = ctx
        logits, caches = self._prefill(self.params, batch, max_len=max_len)

        sample = sampling.SAMPLERS[sampler]
        key = jax.random.PRNGKey(seed)
        pos = lens                       # next position to write per request
        cur = (sample(logits) if sampler == "greedy"
               else sample(logits, key))
        out = [[] for _ in range(b)]
        active = np.ones(b, bool)
        steps = 0
        for step in range(int(budgets.max())):
            cur_np = np.asarray(cur)
            for i in range(b):
                if active[i]:
                    out[i].append(int(cur_np[i]))
                    if self.eos_id is not None and cur_np[i] == self.eos_id:
                        active[i] = False
                    if len(out[i]) >= budgets[i]:
                        active[i] = False
            if not active.any():
                break
            logits, caches = self._decode(self.params, caches, cur, pos)
            key, sub = jax.random.split(key)
            cur = (sample(logits) if sampler == "greedy"
                   else sample(logits, sub))
            pos = pos + 1
            steps += 1
        return GenerationResult(tokens=out, modes=[mode] * b,
                                prompt_lens=[len(p) for p in prompts],
                                steps_run=steps)

    # -- paper-style analysis -------------------------------------------------

    def cot_study(self, prompts, *, max_new=32, sampler="greedy", seed=0):
        """Run all three CoT modes; return per-mode generations + stats
        (Figure 2 lengths / Figure 4 repetition inputs)."""
        results = {}
        for mode in cot.MODES:
            r = self.generate(prompts, max_new=max_new, mode=mode,
                              sampler=sampler, seed=seed)
            results[mode] = {
                "generations": r.tokens,
                "mean_len": float(np.mean([len(t) for t in r.tokens])),
                "repetition_rate": cot.repetition_rate(r.tokens),
            }
        return results

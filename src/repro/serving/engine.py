"""Serving engines: the legacy padded-batch engine and the continuous-
batching engine over the paged, optionally int8-quantized KV pool.

`ServingEngine` (legacy): requests are packed left-aligned into fixed batch
slots with a per-request `lengths` vector against dense per-slot caches;
the whole batch enters and leaves together.

`ContinuousBatchingEngine` (tentpole): a PagedScheduler admits/evicts
requests *each step* into fixed batch slots; KV lives in fixed-size pages
(serving/kv_pool.py) handed out from a free list, so memory scales with
tokens actually held rather than slots x max_len, and finished sequences'
pages are immediately reusable. The three CoT think modes are just
different (directive token, stop policy) pairs feeding the same scheduler
(cot.StopPolicy).

Prefill admission comes in two modes:

  * "chunked" (default, Sarathi/vLLM-style): prompts stream through the
    scheduler in fixed-shape page-aligned chunks of `chunk_pages` pages.
    Each step batches prompt chunks from up to `token_budget` worth of
    prefilling slots *together with* every ongoing decode slot into one
    jitted mixed step (`transformer.prefill_chunk_paged`) whose K/V is
    quantized directly into int8 pages (`kv_pool.write_chunk`) — no dense
    bf16 cache and no second `_to_pages` pass. Steady state compiles
    exactly two programs: the mixed step (any prefill in flight) and the
    pure decode step.
  * "legacy" (per-admission prefill, kept for A/B): each admitted request
    runs a one-shot dense prefill at a power-of-two page bucket, then its
    cache is scattered into pages. One extra compilation per distinct
    bucket; decode stalls while prefill runs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.serving import cot, sampling
from repro.serving.kv_pool import SCRATCH_PAGE, chunk_window_pages
from repro.serving.scheduler import PagedScheduler, Request


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]          # generated tokens per request
    modes: List[str]
    prompt_lens: List[int]
    steps_run: int


class ServingEngine:
    def __init__(self, params, cfg, *, qcfg=None, impl=None, kv_bits=16,
                 eos_id: Optional[int] = None, dtype=jnp.bfloat16):
        self.params = params
        self.cfg = cfg
        self.qcfg = qcfg
        self.impl = impl
        self.kv_bits = kv_bits
        self.eos_id = eos_id
        self.dtype = dtype
        self._prefill = jax.jit(
            partial(transformer.prefill, cfg=cfg, qcfg=qcfg, impl=impl,
                    kv_bits=kv_bits, dtype=dtype),
            static_argnames=("max_len",))
        self._decode = jax.jit(
            partial(transformer.decode_step, cfg=cfg, qcfg=qcfg, impl=impl,
                    dtype=dtype))

    # -- request packing ------------------------------------------------------

    def _pack(self, prompts: Sequence[Sequence[int]]):
        b = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        s = int(lens.max())
        toks = np.zeros((b, s), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return jnp.asarray(toks), jnp.asarray(lens)

    # -- generation -----------------------------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]], *, max_new: int = 32,
                 mode: str = "slow_think", sampler: str = "greedy",
                 seed: int = 0, ctx=None) -> GenerationResult:
        """Generate under a CoT mode. Directive token appended per paper §4.1;
        per-request budgets follow the mode policy (auto_think adapts)."""
        cfg = self.cfg
        prompts = [cot.apply_mode(p, mode, cfg.vocab) for p in prompts]
        budgets = np.array([cot.budget_for(mode, len(p), max_new)
                            for p in prompts], np.int32)
        toks, lens = self._pack(prompts)
        b, s = toks.shape
        max_len = s + int(budgets.max()) + 1

        batch = {"tokens": toks, "lengths": lens}
        if ctx is not None:
            batch["ctx"] = ctx
        logits, caches = self._prefill(self.params, batch, max_len=max_len)

        sample = sampling.SAMPLERS[sampler]
        key = jax.random.PRNGKey(seed)
        pos = lens                       # next position to write per request
        cur = (sample(logits) if sampler == "greedy"
               else sample(logits, key))
        out = [[] for _ in range(b)]
        active = np.ones(b, bool)
        steps = 0
        for step in range(int(budgets.max())):
            cur_np = np.asarray(cur)
            for i in range(b):
                if active[i]:
                    out[i].append(int(cur_np[i]))
                    if self.eos_id is not None and cur_np[i] == self.eos_id:
                        active[i] = False
                    if len(out[i]) >= budgets[i]:
                        active[i] = False
            if not active.any():
                break
            logits, caches = self._decode(self.params, caches, cur, pos)
            key, sub = jax.random.split(key)
            cur = (sample(logits) if sampler == "greedy"
                   else sample(logits, sub))
            pos = pos + 1
            steps += 1
        return GenerationResult(tokens=out, modes=[mode] * b,
                                prompt_lens=[len(p) for p in prompts],
                                steps_run=steps)

    # -- paper-style analysis -------------------------------------------------

    def cot_study(self, prompts, *, max_new=32, sampler="greedy", seed=0):
        """Run all three CoT modes; return per-mode generations + stats
        (Figure 2 lengths / Figure 4 repetition inputs)."""
        results = {}
        for mode in cot.MODES:
            r = self.generate(prompts, max_new=max_new, mode=mode,
                              sampler=sampler, seed=seed)
            results[mode] = {
                "generations": r.tokens,
                "mean_len": float(np.mean([len(t) for t in r.tokens])),
                "repetition_rate": cot.repetition_rate(r.tokens),
            }
        return results


# ---------------------------------------------------------------------------
# Continuous batching over the paged KV pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousResult:
    tokens: List[List[int]]          # generated tokens, submission order
    modes: List[str]
    prompt_lens: List[int]
    steps_run: int                   # pure batched decode steps
    decode_tokens: int               # tokens produced by decode lanes
    evictions: int
    mixed_steps: int = 0             # chunked prefill+decode steps
    prefill_tokens: int = 0          # prompt tokens written via chunks
    prefix_hit_tokens: int = 0       # prompt tokens served from the cache


class ContinuousBatchingEngine:
    """Continuous-batching inference over a paged, optionally int8 KV cache.

    max_batch slots x ceil(max_seq_len / page_size) page-table columns; the
    pool defaults to full occupancy (every slot can reach max_seq_len) —
    pass a smaller n_pages to exercise preemption. Greedy sampling (the
    deterministic serving path the paper's CoT study measures).

    prefix_cache=True (chunked mode only) shares quantized prompt pages
    across requests via the page table: admission maps the longest cached
    prefix of full prompt pages in bit-exact (no recompute), and only the
    uncached tail is chunk-prefilled; finished requests promote their
    prompt pages. Cache hits change page-table *contents*, never step
    shapes, so compile_counts() stays at the two-program steady state.
    """

    def __init__(self, params, cfg, *, qcfg=None, impl=None, kv_bits=16,
                 page_size: int = 16, max_batch: int = 8,
                 max_seq_len: int = 256, n_pages: Optional[int] = None,
                 eos_id: Optional[int] = None, dtype=jnp.bfloat16,
                 paged_impl: str = "xla", prefill_mode: str = "chunked",
                 chunk_pages: int = 2, token_budget: Optional[int] = None,
                 prefix_cache: bool = False):
        assert transformer.supports_paged(cfg), (
            f"paged decode needs full attention over token inputs: "
            f"pattern={cfg.pattern} (supported {transformer.PAGED_PATTERNS}),"
            f" sliding_window={cfg.sliding_window} (need 0), "
            f"frontend={cfg.frontend!r} (need 'tokens')")
        self.params = params
        self.cfg = cfg
        self.page_size = page_size
        self.eos_id = eos_id
        self.max_pages_per_seq = -(-max_seq_len // page_size)
        if n_pages is None:
            n_pages = 1 + max_batch * self.max_pages_per_seq
        self.pools = transformer.init_paged_pools(
            cfg, n_pages, page_size, kv_bits, dtype)
        assert prefill_mode in ("chunked", "legacy"), prefill_mode
        assert not (prefix_cache and prefill_mode == "legacy"), \
            "prefix caching needs chunked prefill (one-shot prefill would " \
            "rewrite shared pages)"
        self.prefix_cache = prefix_cache
        self.sched = PagedScheduler(
            n_slots=max_batch, n_pages=n_pages, page_size=page_size,
            max_pages_per_seq=self.max_pages_per_seq,
            prefix_cache=prefix_cache)
        self.prefill_mode = prefill_mode
        self.chunk_tokens = chunk_pages * page_size
        if self.chunk_tokens > max_seq_len:
            raise ValueError(
                f"chunk_pages {chunk_pages} x page_size {page_size} exceeds "
                f"max_seq_len {max_seq_len}")
        self.window_pages = chunk_window_pages(self.chunk_tokens, page_size)
        # token budget per mixed step: decode lanes cost 1 token each, a
        # prefill chunk costs chunk_tokens; default = one chunk + all lanes
        self.token_budget = (token_budget if token_budget is not None
                             else self.chunk_tokens + max_batch)
        self._last_tok = np.zeros(max_batch, np.int32)
        self._requests: Dict[int, Request] = {}
        self._policies: Dict[int, cot.StopPolicy] = {}
        self._next_rid = 0
        self.steps_run = 0
        self.decode_tokens = 0
        self.mixed_steps = 0
        self.prefill_tokens = 0

        self._prefill = jax.jit(
            partial(transformer.prefill, cfg=cfg, qcfg=qcfg, impl=impl,
                    kv_bits=16, dtype=dtype),
            static_argnames=("max_len",))
        self._decode = jax.jit(
            partial(transformer.decode_step_paged, cfg=cfg, qcfg=qcfg,
                    impl=impl, paged_impl=paged_impl, dtype=dtype))
        self._mixed = jax.jit(
            partial(transformer.prefill_chunk_paged, cfg=cfg, qcfg=qcfg,
                    impl=impl, paged_impl=paged_impl, dtype=dtype))
        self._sample = jax.jit(lambda lg: jnp.argmax(lg, -1).astype(jnp.int32))

        def to_pages(pools, caches, page_rows, lengths):
            from repro.serving import kv_pool
            new = dict(pools)
            for i, c in caches.items():
                new[i] = jax.vmap(kv_pool.write_prefill,
                                  in_axes=(0, 0, 0, None, None))(
                    pools[i], c["k"], c["v"], page_rows, lengths)
            return new

        self._to_pages = jax.jit(to_pages)

    # -- accounting -----------------------------------------------------------

    def kv_bytes_per_token(self) -> float:
        """Whole-model KV bytes per token slot (pages + scales, all blocks
        and groups)."""
        from repro.serving import kv_pool
        n_pages = self.sched.alloc.n_pages
        return sum(kv_pool.pool_bytes(p) for p in self.pools.values()) \
            / (n_pages * self.page_size)

    def compile_counts(self) -> Dict[str, int]:
        """Compilation-cache sizes of the jitted step functions. Chunked
        steady state is exactly {mixed: 1, decode: 1, prefill: 0}; legacy
        pays one `prefill` entry per distinct power-of-two page bucket."""
        return {"prefill": self._prefill._cache_size(),
                "mixed": self._mixed._cache_size(),
                "decode": self._decode._cache_size()}

    def prefix_cache_stats(self) -> Dict[str, float]:
        """Cumulative prefix-cache counters: prompt tokens through
        admission, tokens served from cached pages, the resulting hit
        rate, and the current cached-page census."""
        s = self.sched
        return {"prompt_tokens": s.prefix_prompt_tokens,
                "hit_tokens": s.prefix_hit_tokens,
                "hit_rate": (s.prefix_hit_tokens
                             / max(1, s.prefix_prompt_tokens)),
                "cached_pages": 0 if s.cache is None else s.cache.n_cached,
                "unreferenced_pages": (0 if s.cache is None
                                       else s.cache.n_unreferenced)}

    # -- request lifecycle ----------------------------------------------------

    def submit(self, prompt: Sequence[int], *, mode: str = "slow_think",
               max_new: int = 32) -> int:
        full = cot.apply_mode(prompt, mode, self.cfg.vocab)
        need = -(-len(full) // self.page_size)
        if need > self.sched.alloc.n_pages - 1:
            raise ValueError("prompt larger than the whole page pool")
        budget = cot.budget_for(mode, len(full), max_new)
        cap = self.max_pages_per_seq * self.page_size
        if len(full) + budget > cap:
            raise ValueError(
                f"prompt ({len(full)}) + budget ({budget}) exceeds "
                f"max_seq_len {cap}; raise max_seq_len or lower max_new")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=full, mode=mode, budget=budget)
        self._requests[rid] = req
        self._policies[rid] = cot.policy_for(mode, len(full), max_new,
                                             eos_id=self.eos_id)
        self.sched.submit(req)
        return rid

    def _prefill_one(self, slot: int, req: Request) -> None:
        """Legacy one-shot prefill, bucketed to the next power-of-two page
        count so the compile count is O(log max_seq_len) rather than one
        program per distinct prompt-page count."""
        page = self.page_size
        n = len(req.prompt)
        need = -(-n // page)
        bucket_pages = 1 << (need - 1).bit_length()
        bucket = bucket_pages * page
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt
        lens = jnp.asarray([n], jnp.int32)
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks), "lengths": lens},
            max_len=bucket)
        # bucket rows beyond the prompt's allocation scatter into scratch
        # (write_prefill zeroes positions >= lengths, so the writes are 0s)
        rows = np.full((1, bucket_pages), SCRATCH_PAGE, np.int32)
        rows[0, :need] = self.sched.page_table[slot, :need]
        self.pools = self._to_pages(self.pools, caches, jnp.asarray(rows),
                                    lens)
        self.prefill_tokens += n
        tok = int(np.asarray(self._sample(logits))[0])
        req.out.append(tok)
        self._last_tok[slot] = tok
        if self._policies[req.rid].done(req.out):
            self.sched.complete(slot)

    def step(self) -> bool:
        """One engine step. Returns whether any progress was made."""
        if self.prefill_mode == "legacy":
            return self._step_legacy()
        return self._step_chunked()

    def _step_legacy(self) -> bool:
        """Admit + one-shot prefill per admission, then one batched decode."""
        sched = self.sched
        progressed = False
        while True:
            # re-admit after prefill-time completions free their slots
            admitted = sched.admit()
            if not admitted:
                break
            progressed = True
            for slot, req in admitted:
                self._prefill_one(slot, req)
        sched.ensure_decode_capacity()
        if not sched.active:
            return progressed
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(sched.page_table),
            jnp.asarray(self._last_tok), jnp.asarray(sched.lengths))
        self.steps_run += 1
        nxt = np.asarray(self._sample(logits))
        for slot in list(sched.active):
            req = sched.active[slot]
            sched.lengths[slot] += 1
            tok = int(nxt[slot])
            req.out.append(tok)
            self._last_tok[slot] = tok
            self.decode_tokens += 1
            if self._policies[req.rid].done(req.out):
                sched.complete(slot)
        return True

    # -- chunked prefill ------------------------------------------------------

    def _plan_chunked(self):
        """Pick this step's lanes and secure their pages. Preemption during
        growth can evict lanes already picked (including mid-prefill
        victims), so the plan is recomputed until a pass allocates without
        evicting. Returns (advancing prefill slots, decode slots)."""
        sched = self.sched
        c = self.chunk_tokens
        while True:
            prefilling = sched.prefilling_slots()
            decoding = sched.decoding_slots()
            budget_left = self.token_budget - len(decoding)
            n_adv = max(1, budget_left // c) if prefilling else 0
            advancing = prefilling[:n_adv]
            evicted = False
            for slot in decoding:
                if slot not in sched.active:
                    continue
                if sched.grow_to(slot, int(sched.lengths[slot]) + 1):
                    evicted = True
            for slot in advancing:
                if slot not in sched.active:
                    continue
                req = sched.active[slot]
                prog = int(sched.prefill_progress[slot])
                n_new = min(c, len(req.prompt) - prog)
                if sched.grow_to(slot, prog + n_new):
                    evicted = True
            if not evicted:
                advancing = [s for s in advancing if s in sched.active]
                decoding = [s for s in decoding if s in sched.active]
                return advancing, decoding

    def _step_chunked(self) -> bool:
        """Admit lazily (first chunk's pages only), then run one fixed-shape
        mixed step: prompt chunks for advancing prefill slots, one token for
        each decode slot, idle lanes masked out with n_new = 0."""
        sched = self.sched
        page = self.page_size
        c, wc = self.chunk_tokens, self.window_pages
        progressed = bool(sched.admit(max_prefill_pages=c // page))
        if not sched.active:
            return progressed
        advancing, decoding = self._plan_chunked()

        if not advancing:
            # steady-state decode: same compiled program as legacy decode
            logits, self.pools = self._decode(
                self.params, self.pools, jnp.asarray(sched.page_table),
                jnp.asarray(self._last_tok), jnp.asarray(sched.lengths))
            self.steps_run += 1
        else:
            b = sched.n_slots
            toks = np.zeros((b, c), np.int32)
            q_start = np.zeros(b, np.int32)
            n_new = np.zeros(b, np.int32)
            windows = np.full((b, wc), SCRATCH_PAGE, np.int32)

            def fill_window(slot, start):
                pidx0 = start // page
                row = sched.page_table[slot]
                take = min(wc, row.shape[0] - pidx0)
                windows[slot, :take] = row[pidx0:pidx0 + take]

            for slot in advancing:
                req = sched.active[slot]
                prog = int(sched.prefill_progress[slot])
                n = min(c, len(req.prompt) - prog)
                toks[slot, :n] = req.prompt[prog:prog + n]
                q_start[slot] = prog
                n_new[slot] = n
                fill_window(slot, prog)
            for slot in decoding:
                start = int(sched.lengths[slot])
                toks[slot, 0] = self._last_tok[slot]
                q_start[slot] = start
                n_new[slot] = 1
                fill_window(slot, start)

            logits, self.pools = self._mixed(
                self.params, self.pools, jnp.asarray(sched.page_table),
                jnp.asarray(windows), jnp.asarray(toks),
                jnp.asarray(q_start), jnp.asarray(n_new))
            self.mixed_steps += 1

        nxt = np.asarray(self._sample(logits))
        for slot in advancing:
            req = sched.active[slot]
            n = int(n_new[slot])
            sched.prefill_progress[slot] += n
            sched.lengths[slot] += n
            self.prefill_tokens += n
            if int(sched.prefill_progress[slot]) == len(req.prompt):
                # prompt fully in cache: logits at its last token yield the
                # first generated token (as legacy prefill does)
                tok = int(nxt[slot])
                req.out.append(tok)
                self._last_tok[slot] = tok
                if self._policies[req.rid].done(req.out):
                    sched.complete(slot)
        for slot in decoding:
            req = sched.active[slot]
            sched.lengths[slot] += 1
            tok = int(nxt[slot])
            req.out.append(tok)
            self._last_tok[slot] = tok
            self.decode_tokens += 1
            if self._policies[req.rid].done(req.out):
                sched.complete(slot)
        return True

    def run(self, prompts: Sequence[Sequence[int]], *,
            mode: str = "slow_think", max_new: int = 32,
            max_steps: int = 100_000) -> ContinuousResult:
        rids = [self.submit(p, mode=mode, max_new=max_new) for p in prompts]
        steps0, tokens0 = self.steps_run, self.decode_tokens
        evict0 = self.sched.n_evictions
        mixed0, pf0 = self.mixed_steps, self.prefill_tokens
        hit0 = self.sched.prefix_hit_tokens
        steps = 0
        while not self.sched.idle:
            progressed = self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("continuous engine exceeded max_steps")
            if not progressed and not self.sched.idle:
                raise RuntimeError("scheduler stalled with pending work")
        reqs = [self._requests[r] for r in rids]
        return ContinuousResult(
            tokens=[r.out for r in reqs],
            modes=[r.mode for r in reqs],
            prompt_lens=[len(r.prompt) for r in reqs],
            steps_run=self.steps_run - steps0,
            decode_tokens=self.decode_tokens - tokens0,
            evictions=self.sched.n_evictions - evict0,
            mixed_steps=self.mixed_steps - mixed0,
            prefill_tokens=self.prefill_tokens - pf0,
            prefix_hit_tokens=self.sched.prefix_hit_tokens - hit0)

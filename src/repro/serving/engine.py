"""Serving engines: the legacy padded-batch engine and the continuous-
batching engine over the paged, optionally int8-quantized KV pool.

`ServingEngine` (legacy): requests are packed left-aligned into fixed batch
slots with a per-request `lengths` vector against dense per-slot caches;
the whole batch enters and leaves together.

`ContinuousBatchingEngine` (tentpole): a PagedScheduler admits/evicts
requests *each step* into fixed batch slots; KV lives in fixed-size pages
(serving/kv_pool.py) handed out from a free list, so memory scales with
tokens actually held rather than slots x max_len, and finished sequences'
pages are immediately reusable. The three CoT think modes are just
different (directive token, stop policy) pairs feeding the same scheduler
(cot.StopPolicy). Decode runs one jitted `transformer.decode_step_paged`
over all slots; prefill runs per admission at page-bucketed lengths and is
scattered into pages.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.serving import cot, sampling
from repro.serving.scheduler import PagedScheduler, Request


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]          # generated tokens per request
    modes: List[str]
    prompt_lens: List[int]
    steps_run: int


class ServingEngine:
    def __init__(self, params, cfg, *, qcfg=None, impl=None, kv_bits=16,
                 eos_id: Optional[int] = None, dtype=jnp.bfloat16):
        self.params = params
        self.cfg = cfg
        self.qcfg = qcfg
        self.impl = impl
        self.kv_bits = kv_bits
        self.eos_id = eos_id
        self.dtype = dtype
        self._prefill = jax.jit(
            partial(transformer.prefill, cfg=cfg, qcfg=qcfg, impl=impl,
                    kv_bits=kv_bits, dtype=dtype),
            static_argnames=("max_len",))
        self._decode = jax.jit(
            partial(transformer.decode_step, cfg=cfg, qcfg=qcfg, impl=impl,
                    dtype=dtype))

    # -- request packing ------------------------------------------------------

    def _pack(self, prompts: Sequence[Sequence[int]]):
        b = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        s = int(lens.max())
        toks = np.zeros((b, s), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return jnp.asarray(toks), jnp.asarray(lens)

    # -- generation -----------------------------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]], *, max_new: int = 32,
                 mode: str = "slow_think", sampler: str = "greedy",
                 seed: int = 0, ctx=None) -> GenerationResult:
        """Generate under a CoT mode. Directive token appended per paper §4.1;
        per-request budgets follow the mode policy (auto_think adapts)."""
        cfg = self.cfg
        prompts = [cot.apply_mode(p, mode, cfg.vocab) for p in prompts]
        budgets = np.array([cot.budget_for(mode, len(p), max_new)
                            for p in prompts], np.int32)
        toks, lens = self._pack(prompts)
        b, s = toks.shape
        max_len = s + int(budgets.max()) + 1

        batch = {"tokens": toks, "lengths": lens}
        if ctx is not None:
            batch["ctx"] = ctx
        logits, caches = self._prefill(self.params, batch, max_len=max_len)

        sample = sampling.SAMPLERS[sampler]
        key = jax.random.PRNGKey(seed)
        pos = lens                       # next position to write per request
        cur = (sample(logits) if sampler == "greedy"
               else sample(logits, key))
        out = [[] for _ in range(b)]
        active = np.ones(b, bool)
        steps = 0
        for step in range(int(budgets.max())):
            cur_np = np.asarray(cur)
            for i in range(b):
                if active[i]:
                    out[i].append(int(cur_np[i]))
                    if self.eos_id is not None and cur_np[i] == self.eos_id:
                        active[i] = False
                    if len(out[i]) >= budgets[i]:
                        active[i] = False
            if not active.any():
                break
            logits, caches = self._decode(self.params, caches, cur, pos)
            key, sub = jax.random.split(key)
            cur = (sample(logits) if sampler == "greedy"
                   else sample(logits, sub))
            pos = pos + 1
            steps += 1
        return GenerationResult(tokens=out, modes=[mode] * b,
                                prompt_lens=[len(p) for p in prompts],
                                steps_run=steps)

    # -- paper-style analysis -------------------------------------------------

    def cot_study(self, prompts, *, max_new=32, sampler="greedy", seed=0):
        """Run all three CoT modes; return per-mode generations + stats
        (Figure 2 lengths / Figure 4 repetition inputs)."""
        results = {}
        for mode in cot.MODES:
            r = self.generate(prompts, max_new=max_new, mode=mode,
                              sampler=sampler, seed=seed)
            results[mode] = {
                "generations": r.tokens,
                "mean_len": float(np.mean([len(t) for t in r.tokens])),
                "repetition_rate": cot.repetition_rate(r.tokens),
            }
        return results


# ---------------------------------------------------------------------------
# Continuous batching over the paged KV pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousResult:
    tokens: List[List[int]]          # generated tokens, submission order
    modes: List[str]
    prompt_lens: List[int]
    steps_run: int                   # batched decode steps
    decode_tokens: int               # tokens produced by decode steps
    evictions: int


class ContinuousBatchingEngine:
    """Continuous-batching inference over a paged, optionally int8 KV cache.

    max_batch slots x ceil(max_seq_len / page_size) page-table columns; the
    pool defaults to full occupancy (every slot can reach max_seq_len) —
    pass a smaller n_pages to exercise preemption. Greedy sampling (the
    deterministic serving path the paper's CoT study measures).
    """

    def __init__(self, params, cfg, *, qcfg=None, impl=None, kv_bits=16,
                 page_size: int = 16, max_batch: int = 8,
                 max_seq_len: int = 256, n_pages: Optional[int] = None,
                 eos_id: Optional[int] = None, dtype=jnp.bfloat16,
                 paged_impl: str = "xla"):
        assert transformer.supports_paged(cfg), (
            f"paged decode needs full attention over token inputs: "
            f"pattern={cfg.pattern} (supported {transformer.PAGED_PATTERNS}),"
            f" sliding_window={cfg.sliding_window} (need 0), "
            f"frontend={cfg.frontend!r} (need 'tokens')")
        self.params = params
        self.cfg = cfg
        self.page_size = page_size
        self.eos_id = eos_id
        self.max_pages_per_seq = -(-max_seq_len // page_size)
        if n_pages is None:
            n_pages = 1 + max_batch * self.max_pages_per_seq
        self.pools = transformer.init_paged_pools(
            cfg, n_pages, page_size, kv_bits, dtype)
        self.sched = PagedScheduler(
            n_slots=max_batch, n_pages=n_pages, page_size=page_size,
            max_pages_per_seq=self.max_pages_per_seq)
        self._last_tok = np.zeros(max_batch, np.int32)
        self._requests: Dict[int, Request] = {}
        self._policies: Dict[int, cot.StopPolicy] = {}
        self._next_rid = 0
        self.steps_run = 0
        self.decode_tokens = 0

        self._prefill = jax.jit(
            partial(transformer.prefill, cfg=cfg, qcfg=qcfg, impl=impl,
                    kv_bits=16, dtype=dtype),
            static_argnames=("max_len",))
        self._decode = jax.jit(
            partial(transformer.decode_step_paged, cfg=cfg, qcfg=qcfg,
                    impl=impl, paged_impl=paged_impl, dtype=dtype))
        self._sample = jax.jit(lambda lg: jnp.argmax(lg, -1).astype(jnp.int32))

        def to_pages(pools, caches, page_rows, lengths):
            from repro.serving import kv_pool
            new = dict(pools)
            for i, c in caches.items():
                new[i] = jax.vmap(kv_pool.write_prefill,
                                  in_axes=(0, 0, 0, None, None))(
                    pools[i], c["k"], c["v"], page_rows, lengths)
            return new

        self._to_pages = jax.jit(to_pages)

    # -- accounting -----------------------------------------------------------

    def kv_bytes_per_token(self) -> float:
        """Whole-model KV bytes per token slot (pages + scales, all blocks
        and groups)."""
        from repro.serving import kv_pool
        n_pages = self.sched.alloc.n_pages
        return sum(kv_pool.pool_bytes(p) for p in self.pools.values()) \
            / (n_pages * self.page_size)

    # -- request lifecycle ----------------------------------------------------

    def submit(self, prompt: Sequence[int], *, mode: str = "slow_think",
               max_new: int = 32) -> int:
        full = cot.apply_mode(prompt, mode, self.cfg.vocab)
        need = -(-len(full) // self.page_size)
        if need > self.sched.alloc.n_pages - 1:
            raise ValueError("prompt larger than the whole page pool")
        budget = cot.budget_for(mode, len(full), max_new)
        cap = self.max_pages_per_seq * self.page_size
        if len(full) + budget > cap:
            raise ValueError(
                f"prompt ({len(full)}) + budget ({budget}) exceeds "
                f"max_seq_len {cap}; raise max_seq_len or lower max_new")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=full, mode=mode, budget=budget)
        self._requests[rid] = req
        self._policies[rid] = cot.policy_for(mode, len(full), max_new,
                                             eos_id=self.eos_id)
        self.sched.submit(req)
        return rid

    def _prefill_one(self, slot: int, req: Request) -> None:
        page = self.page_size
        n = len(req.prompt)
        need = -(-n // page)
        bucket = need * page
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt
        lens = jnp.asarray([n], jnp.int32)
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks), "lengths": lens},
            max_len=bucket)
        rows = jnp.asarray(self.sched.page_table[slot:slot + 1, :need])
        self.pools = self._to_pages(self.pools, caches, rows, lens)
        tok = int(np.asarray(self._sample(logits))[0])
        req.out.append(tok)
        self._last_tok[slot] = tok
        if self._policies[req.rid].done(req.out):
            self.sched.complete(slot)

    def step(self) -> bool:
        """One engine step: admit + prefill, ensure pages, batched decode.
        Returns whether any progress was made (admission or decode)."""
        sched = self.sched
        progressed = False
        while True:
            # re-admit after prefill-time completions free their slots
            admitted = sched.admit()
            if not admitted:
                break
            progressed = True
            for slot, req in admitted:
                self._prefill_one(slot, req)
        sched.ensure_decode_capacity()
        if not sched.active:
            return progressed
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(sched.page_table),
            jnp.asarray(self._last_tok), jnp.asarray(sched.lengths))
        self.steps_run += 1
        nxt = np.asarray(self._sample(logits))
        for slot in list(sched.active):
            req = sched.active[slot]
            sched.lengths[slot] += 1
            tok = int(nxt[slot])
            req.out.append(tok)
            self._last_tok[slot] = tok
            self.decode_tokens += 1
            if self._policies[req.rid].done(req.out):
                sched.complete(slot)
        return True

    def run(self, prompts: Sequence[Sequence[int]], *,
            mode: str = "slow_think", max_new: int = 32,
            max_steps: int = 100_000) -> ContinuousResult:
        rids = [self.submit(p, mode=mode, max_new=max_new) for p in prompts]
        steps0, tokens0 = self.steps_run, self.decode_tokens
        evict0 = self.sched.n_evictions
        steps = 0
        while not self.sched.idle:
            progressed = self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("continuous engine exceeded max_steps")
            if not progressed and not self.sched.idle:
                raise RuntimeError("scheduler stalled with pending work")
        reqs = [self._requests[r] for r in rids]
        return ContinuousResult(
            tokens=[r.out for r in reqs],
            modes=[r.mode for r in reqs],
            prompt_lens=[len(r.prompt) for r in reqs],
            steps_run=self.steps_run - steps0,
            decode_tokens=self.decode_tokens - tokens0,
            evictions=self.sched.n_evictions - evict0)

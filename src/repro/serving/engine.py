"""Serving engines: the legacy padded-batch engine and the continuous-
batching engine over the paged, optionally int8-quantized KV pool.

`ServingEngine` (legacy): requests are packed left-aligned into fixed batch
slots with a per-request `lengths` vector against dense per-slot caches;
the whole batch enters and leaves together.

`ContinuousBatchingEngine` (tentpole): a PagedScheduler admits/evicts
requests *each step* into fixed batch slots; KV lives in fixed-size pages
(serving/kv_pool.py) handed out from a free list, so memory scales with
tokens actually held rather than slots x max_len, and finished sequences'
pages are immediately reusable. The three CoT think modes are just
different (directive token, stop policy) pairs feeding the same scheduler
(cot.StopPolicy).

Prefill admission comes in two modes:

  * "chunked" (default, Sarathi/vLLM-style): prompts stream through the
    scheduler in fixed-shape page-aligned chunks of `chunk_pages` pages.
    Each step batches prompt chunks from up to `token_budget` worth of
    prefilling slots *together with* every ongoing decode slot into one
    jitted mixed step (`transformer.prefill_chunk_paged`) whose K/V is
    quantized directly into int8 pages (`kv_pool.write_chunk`) — no dense
    bf16 cache and no second `_to_pages` pass. Steady state compiles
    exactly two programs: the mixed step (any prefill in flight) and the
    pure decode step.
  * "legacy" (per-admission prefill, kept for A/B): each admitted request
    runs a one-shot dense prefill at a power-of-two page bucket, then its
    cache is scattered into pages. One extra compilation per distinct
    bucket; decode stalls while prefill runs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.serving import cot, sampling
from repro.serving.draft import NgramDrafter
from repro.serving.kv_pool import (SCRATCH_PAGE, chunk_window_pages,
                                   verify_window_pages)
from repro.serving.scheduler import PagedScheduler, Request


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]          # generated tokens per request
    modes: List[str]
    prompt_lens: List[int]
    steps_run: int


class ServingEngine:
    def __init__(self, params, cfg, *, qcfg=None, impl=None, kv_bits=16,
                 eos_id: Optional[int] = None, dtype=jnp.bfloat16):
        self.params = params
        self.cfg = cfg
        self.qcfg = qcfg
        self.impl = impl
        self.kv_bits = kv_bits
        self.eos_id = eos_id
        self.dtype = dtype
        self._prefill = jax.jit(
            partial(transformer.prefill, cfg=cfg, qcfg=qcfg, impl=impl,
                    kv_bits=kv_bits, dtype=dtype),
            static_argnames=("max_len",))
        self._decode = jax.jit(
            partial(transformer.decode_step, cfg=cfg, qcfg=qcfg, impl=impl,
                    dtype=dtype))

    # -- request packing ------------------------------------------------------

    def _pack(self, prompts: Sequence[Sequence[int]]):
        b = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        s = int(lens.max())
        toks = np.zeros((b, s), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return jnp.asarray(toks), jnp.asarray(lens)

    # -- generation -----------------------------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]], *, max_new: int = 32,
                 mode: str = "slow_think", sampler: str = "greedy",
                 seed: int = 0, ctx=None) -> GenerationResult:
        """Generate under a CoT mode. Directive token appended per paper §4.1;
        per-request budgets follow the mode policy (auto_think adapts)."""
        cfg = self.cfg
        prompts = [cot.apply_mode(p, mode, cfg.vocab) for p in prompts]
        budgets = np.array([cot.budget_for(mode, len(p), max_new)
                            for p in prompts], np.int32)
        toks, lens = self._pack(prompts)
        b, s = toks.shape
        max_len = s + int(budgets.max()) + 1

        batch = {"tokens": toks, "lengths": lens}
        if ctx is not None:
            batch["ctx"] = ctx
        logits, caches = self._prefill(self.params, batch, max_len=max_len)

        sample = sampling.SAMPLERS[sampler]
        key = jax.random.PRNGKey(seed)
        pos = lens                       # next position to write per request
        cur = (sample(logits) if sampler == "greedy"
               else sample(logits, key))
        out = [[] for _ in range(b)]
        active = np.ones(b, bool)
        steps = 0
        for step in range(int(budgets.max())):
            cur_np = np.asarray(cur)
            for i in range(b):
                if active[i]:
                    out[i].append(int(cur_np[i]))
                    if self.eos_id is not None and cur_np[i] == self.eos_id:
                        active[i] = False
                    if len(out[i]) >= budgets[i]:
                        active[i] = False
            if not active.any():
                break
            logits, caches = self._decode(self.params, caches, cur, pos)
            key, sub = jax.random.split(key)
            cur = (sample(logits) if sampler == "greedy"
                   else sample(logits, sub))
            pos = pos + 1
            steps += 1
        return GenerationResult(tokens=out, modes=[mode] * b,
                                prompt_lens=[len(p) for p in prompts],
                                steps_run=steps)

    # -- paper-style analysis -------------------------------------------------

    def cot_study(self, prompts, *, max_new=32, sampler="greedy", seed=0):
        """Run all three CoT modes; return per-mode generations + stats
        (Figure 2 lengths / Figure 4 repetition inputs)."""
        results = {}
        for mode in cot.MODES:
            r = self.generate(prompts, max_new=max_new, mode=mode,
                              sampler=sampler, seed=seed)
            results[mode] = {
                "generations": r.tokens,
                "mean_len": float(np.mean([len(t) for t in r.tokens])),
                "repetition_rate": cot.repetition_rate(r.tokens),
            }
        return results


# ---------------------------------------------------------------------------
# Continuous batching over the paged KV pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousResult:
    tokens: List[List[int]]          # generated tokens, submission order
    modes: List[str]
    prompt_lens: List[int]
    steps_run: int                   # pure batched decode steps
    decode_tokens: int               # tokens produced by decode lanes
    evictions: int
    mixed_steps: int = 0             # chunked prefill+decode steps
    prefill_tokens: int = 0          # prompt tokens written via chunks
    prefix_hit_tokens: int = 0       # prompt tokens served from the cache
    spec_steps: int = 0              # speculative verify steps
    draft_tokens: int = 0            # drafter proposals scored
    accepted_tokens: int = 0         # proposals accepted (excl. bonus)


class ContinuousBatchingEngine:
    """Continuous-batching inference over a paged, optionally int8 KV cache.

    max_batch slots x ceil(max_seq_len / page_size) page-table columns; the
    pool defaults to full occupancy (every slot can reach max_seq_len) —
    pass a smaller n_pages to exercise preemption. Greedy sampling (the
    deterministic serving path the paper's CoT study measures).

    prefix_cache=True (chunked mode only) shares quantized prompt pages
    across requests via the page table: admission maps the longest cached
    prefix of full prompt pages in bit-exact (no recompute), and only the
    uncached tail is chunk-prefilled; finished requests promote their
    prompt pages. Cache hits change page-table *contents*, never step
    shapes, so compile_counts() stays at the two-program steady state.

    spec_decode=k (chunked mode only) turns on draft-free self-speculative
    decoding: in pure-decode steps an n-gram prompt-lookup drafter
    (serving/draft.py) proposes up to k tokens per lane and one jitted
    verify program (fixed k+1 window — at most one extra compilation)
    scores them all, committing accepted prefixes through the fused
    quantize-on-write path and rolling back rejected suffixes page-exactly
    (kv_pool.truncate + scheduler.truncate_to). With sampler="greedy" and
    bf16 pools the emitted tokens are bit-exact with vanilla greedy decode
    (quantized int8/int4 pools score the draft window's K/V
    pre-quantization, a deviation within quantization noise);
    sampler="temperature" accepts via
    rejection sampling (sampling.speculative_accept), preserving the
    target distribution. A cost-model gate bounds the overhead on
    n-gram-free workloads: a verify step only runs when the drafted total
    times a running acceptance estimate clears spec_gate extra tokens per
    lane (the measured verify/decode cost ratio), and consecutive thin
    drafting backs the host-side lookup off exponentially (doubling
    cooldown capped at spec_cooldown decode steps).
    """

    def __init__(self, params, cfg, *, qcfg=None, impl=None, kv_bits=16,
                 page_size: int = 16, max_batch: int = 8,
                 max_seq_len: int = 256, n_pages: Optional[int] = None,
                 eos_id: Optional[int] = None, dtype=jnp.bfloat16,
                 paged_impl: str = "xla", prefill_mode: str = "chunked",
                 chunk_pages: int = 2, token_budget: Optional[int] = None,
                 prefix_cache: bool = False, spec_decode: int = 0,
                 sampler: str = "greedy", temperature: float = 0.8,
                 top_p: float = 1.0, seed: int = 0,
                 spec_ngram_max: int = 3, spec_ngram_min: int = 2,
                 spec_gate: float = 1.5, spec_cooldown: int = 64):
        assert transformer.supports_paged(cfg), (
            f"paged decode needs full attention over token inputs: "
            f"pattern={cfg.pattern} (supported {transformer.PAGED_PATTERNS}),"
            f" sliding_window={cfg.sliding_window} (need 0), "
            f"frontend={cfg.frontend!r} (need 'tokens')")
        assert kv_bits in (16, 8, 4), \
            f"kv_bits must be 16, 8 or 4 (packed int4); got {kv_bits}"
        self.params = params
        self.cfg = cfg
        self.page_size = page_size
        self.eos_id = eos_id
        self.max_pages_per_seq = -(-max_seq_len // page_size)
        if n_pages is None:
            n_pages = 1 + max_batch * self.max_pages_per_seq
        self.pools = transformer.init_paged_pools(
            cfg, n_pages, page_size, kv_bits, dtype)
        assert prefill_mode in ("chunked", "legacy"), prefill_mode
        assert not (prefix_cache and prefill_mode == "legacy"), \
            "prefix caching needs chunked prefill (one-shot prefill would " \
            "rewrite shared pages)"
        self.prefix_cache = prefix_cache
        self.sched = PagedScheduler(
            n_slots=max_batch, n_pages=n_pages, page_size=page_size,
            max_pages_per_seq=self.max_pages_per_seq,
            prefix_cache=prefix_cache)
        self.prefill_mode = prefill_mode
        self.chunk_tokens = chunk_pages * page_size
        if self.chunk_tokens > max_seq_len:
            raise ValueError(
                f"chunk_pages {chunk_pages} x page_size {page_size} exceeds "
                f"max_seq_len {max_seq_len}")
        self.window_pages = chunk_window_pages(self.chunk_tokens, page_size)
        # token budget per mixed step: decode lanes cost 1 token each, a
        # prefill chunk costs chunk_tokens; default = one chunk + all lanes
        self.token_budget = (token_budget if token_budget is not None
                             else self.chunk_tokens + max_batch)
        assert sampler in ("greedy", "temperature"), sampler
        assert spec_decode >= 0, spec_decode
        assert not (spec_decode and prefill_mode == "legacy"), \
            "speculative decoding needs chunked prefill (the verify step " \
            "reuses the chunk-attention machinery)"
        self.sampler = sampler
        self.temperature = temperature
        self.top_p = top_p
        self._key = jax.random.PRNGKey(seed)
        self.spec_k = spec_decode
        self.spec_tokens = spec_decode + 1          # window width k+1
        self.spec_window_pages = verify_window_pages(self.spec_tokens,
                                                     page_size)
        self._drafter = NgramDrafter(max(1, spec_decode),
                                     ngram_max=spec_ngram_max,
                                     ngram_min=spec_ngram_min)
        self.spec_gate = spec_gate
        self.spec_cooldown = spec_cooldown
        self._spec_off = 0                          # cooldown steps left
        self._gate_cool = 2                         # doubles per miss streak
        self._gate_misses = 0                       # consecutive thin-draft steps
        self._acc_est = 0.5                         # per-proposal EMA, optimistic
        self._last_tok = np.zeros(max_batch, np.int32)
        self._requests: Dict[int, Request] = {}
        self._policies: Dict[int, cot.StopPolicy] = {}
        self._next_rid = 0
        self.steps_run = 0
        self.decode_tokens = 0
        self.mixed_steps = 0
        self.prefill_tokens = 0
        self.spec_steps = 0
        self.draft_tokens = 0
        self.accepted_tokens = 0

        self._prefill = jax.jit(
            partial(transformer.prefill, cfg=cfg, qcfg=qcfg, impl=impl,
                    kv_bits=16, dtype=dtype),
            static_argnames=("max_len",))
        # The pool buffers are donated into every steady-state program:
        # each step rewrites a page or two of multi-MB pools, and without
        # input-output aliasing XLA copies every pool leaf per step. All
        # call sites immediately rebind self.pools to the returned pools.
        self._decode = jax.jit(
            partial(transformer.decode_step_paged, cfg=cfg, qcfg=qcfg,
                    impl=impl, paged_impl=paged_impl, dtype=dtype),
            donate_argnums=(1,))
        self._mixed = jax.jit(
            partial(transformer.prefill_chunk_paged, cfg=cfg, qcfg=qcfg,
                    impl=impl, paged_impl=paged_impl, dtype=dtype),
            donate_argnums=(1,))
        self._sample = jax.jit(lambda lg: jnp.argmax(lg, -1).astype(jnp.int32))
        self._sample_t = jax.jit(partial(sampling.top_p, p=top_p,
                                         temp=temperature))

        def verify_fn(params, pools, page_table, window_rows, tokens,
                      q_start, n_new, key):
            # score the whole draft window read-only (the window's raw K/V
            # is spliced into the attention read, so a rejected suffix
            # never touches the pool), accept a prefix, then commit only
            # the accepted tokens through the fused quantize-on-write path
            from repro.serving import kv_pool
            logits, kv_win = transformer.verify_step_paged(
                params, pools, page_table, tokens, q_start, n_new, cfg,
                qcfg=qcfg, impl=impl, paged_impl=paged_impl, dtype=dtype)
            emit, acc = sampling.speculative_accept(
                logits.astype(jnp.float32), tokens, n_new, key,
                mode=self.sampler, temp=temperature, top_p=top_p)
            n_keep = jnp.where(n_new > 0, acc + 1, 0)
            out_pools = {}
            for i in pools:
                kw, vw = kv_win[i]
                out_pools[i] = jax.vmap(
                    kv_pool.write_chunk,
                    in_axes=(0, 0, 0, None, None, None))(
                    pools[i], kw, vw, window_rows, q_start, n_keep)
            return emit, acc, out_pools

        self._verify = jax.jit(verify_fn, donate_argnums=(1,))
        self._zero_key = jax.random.PRNGKey(0)

        def to_pages(pools, caches, page_rows, lengths):
            from repro.serving import kv_pool
            new = dict(pools)
            for i, c in caches.items():
                new[i] = jax.vmap(kv_pool.write_prefill,
                                  in_axes=(0, 0, 0, None, None))(
                    pools[i], c["k"], c["v"], page_rows, lengths)
            return new

        self._to_pages = jax.jit(to_pages)

    # -- accounting -----------------------------------------------------------

    def kv_bytes_per_token(self) -> float:
        """Whole-model KV bytes per token slot (pages + scales, all blocks
        and groups)."""
        from repro.serving import kv_pool
        n_pages = self.sched.alloc.n_pages
        return sum(kv_pool.pool_bytes(p) for p in self.pools.values()) \
            / (n_pages * self.page_size)

    def compile_counts(self) -> Dict[str, int]:
        """Compilation-cache sizes of the jitted step functions. Chunked
        steady state is exactly {mixed: 1, decode: 1, prefill: 0,
        verify: 0}; --spec-decode adds at most one `verify` program
        (fixed k+1 window shape); legacy pays one `prefill` entry per
        distinct power-of-two page bucket."""
        return {"prefill": self._prefill._cache_size(),
                "mixed": self._mixed._cache_size(),
                "decode": self._decode._cache_size(),
                "verify": self._verify._cache_size()}

    def spec_stats(self) -> Dict[str, float]:
        """Cumulative speculative-decoding counters and acceptance rate
        (accepted drafter proposals / proposals scored; the bonus token
        every verify step emits is not counted on either side)."""
        return {"spec_steps": self.spec_steps,
                "draft_tokens": self.draft_tokens,
                "accepted_tokens": self.accepted_tokens,
                "acceptance_rate": (self.accepted_tokens
                                    / max(1, self.draft_tokens))}

    def prefix_cache_stats(self) -> Dict[str, float]:
        """Cumulative prefix-cache counters: prompt tokens through
        admission, tokens served from cached pages, the resulting hit
        rate, and the current cached-page census."""
        s = self.sched
        return {"prompt_tokens": s.prefix_prompt_tokens,
                "hit_tokens": s.prefix_hit_tokens,
                "hit_rate": (s.prefix_hit_tokens
                             / max(1, s.prefix_prompt_tokens)),
                "cached_pages": 0 if s.cache is None else s.cache.n_cached,
                "unreferenced_pages": (0 if s.cache is None
                                       else s.cache.n_unreferenced)}

    # -- request lifecycle ----------------------------------------------------

    def submit(self, prompt: Sequence[int], *, mode: str = "slow_think",
               max_new: int = 32) -> int:
        full = cot.apply_mode(prompt, mode, self.cfg.vocab)
        need = -(-len(full) // self.page_size)
        if need > self.sched.alloc.n_pages - 1:
            raise ValueError("prompt larger than the whole page pool")
        budget = cot.budget_for(mode, len(full), max_new)
        cap = self.max_pages_per_seq * self.page_size
        if len(full) + budget > cap:
            raise ValueError(
                f"prompt ({len(full)}) + budget ({budget}) exceeds "
                f"max_seq_len {cap}; raise max_seq_len or lower max_new")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=full, mode=mode, budget=budget)
        self._requests[rid] = req
        self._policies[rid] = cot.policy_for(mode, len(full), max_new,
                                             eos_id=self.eos_id)
        self.sched.submit(req)
        return rid

    def _prefill_one(self, slot: int, req: Request) -> None:
        """Legacy one-shot prefill, bucketed to the next power-of-two page
        count so the compile count is O(log max_seq_len) rather than one
        program per distinct prompt-page count."""
        page = self.page_size
        n = len(req.prompt)
        need = -(-n // page)
        bucket_pages = 1 << (need - 1).bit_length()
        bucket = bucket_pages * page
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt
        lens = jnp.asarray([n], jnp.int32)
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks), "lengths": lens},
            max_len=bucket)
        # bucket rows beyond the prompt's allocation scatter into scratch
        # (write_prefill zeroes positions >= lengths, so the writes are 0s)
        rows = np.full((1, bucket_pages), SCRATCH_PAGE, np.int32)
        rows[0, :need] = self.sched.page_table[slot, :need]
        self.pools = self._to_pages(self.pools, caches, jnp.asarray(rows),
                                    lens)
        self.prefill_tokens += n
        tok = int(self._sample_tokens(logits)[0])
        req.out.append(tok)
        self._last_tok[slot] = tok
        if self._policies[req.rid].done(req.out):
            self.sched.complete(slot)

    def _sample_tokens(self, logits) -> np.ndarray:
        """Sample next tokens per lane under the engine's sampler: greedy
        argmax (keyless, deterministic — the path the CoT study measures)
        or temperature with nucleus filtering (top_p=1.0 disables it)."""
        if self.sampler == "greedy":
            return np.asarray(self._sample(logits))
        self._key, sub = jax.random.split(self._key)
        return np.asarray(self._sample_t(logits, sub))

    def step(self) -> bool:
        """One engine step. Returns whether any progress was made."""
        if self.prefill_mode == "legacy":
            return self._step_legacy()
        return self._step_chunked()

    def _step_legacy(self) -> bool:
        """Admit + one-shot prefill per admission, then one batched decode."""
        sched = self.sched
        progressed = False
        while True:
            # re-admit after prefill-time completions free their slots
            admitted = sched.admit()
            if not admitted:
                break
            progressed = True
            for slot, req in admitted:
                self._prefill_one(slot, req)
        sched.ensure_decode_capacity()
        if not sched.active:
            return progressed
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(sched.page_table),
            jnp.asarray(self._last_tok), jnp.asarray(sched.lengths))
        self.steps_run += 1
        nxt = self._sample_tokens(logits)
        for slot in list(sched.active):
            req = sched.active[slot]
            sched.lengths[slot] += 1
            tok = int(nxt[slot])
            req.out.append(tok)
            self._last_tok[slot] = tok
            self.decode_tokens += 1
            if self._policies[req.rid].done(req.out):
                sched.complete(slot)
        return True

    # -- chunked prefill ------------------------------------------------------

    def _plan_chunked(self):
        """Pick this step's lanes and secure their pages. Preemption during
        growth can evict lanes already picked (including mid-prefill
        victims), so the plan is recomputed until a pass allocates without
        evicting. Returns (advancing prefill slots, decode slots)."""
        sched = self.sched
        c = self.chunk_tokens
        while True:
            prefilling = sched.prefilling_slots()
            decoding = sched.decoding_slots()
            budget_left = self.token_budget - len(decoding)
            n_adv = max(1, budget_left // c) if prefilling else 0
            advancing = prefilling[:n_adv]
            evicted = False
            for slot in decoding:
                if slot not in sched.active:
                    continue
                if sched.grow_to(slot, int(sched.lengths[slot]) + 1):
                    evicted = True
            for slot in advancing:
                if slot not in sched.active:
                    continue
                req = sched.active[slot]
                prog = int(sched.prefill_progress[slot])
                n_new = min(c, len(req.prompt) - prog)
                if sched.grow_to(slot, prog + n_new):
                    evicted = True
            if not evicted:
                advancing = [s for s in advancing if s in sched.active]
                decoding = [s for s in decoding if s in sched.active]
                return advancing, decoding

    def _step_chunked(self) -> bool:
        """Admit lazily (first chunk's pages only), then run one fixed-shape
        mixed step: prompt chunks for advancing prefill slots, one token for
        each decode slot, idle lanes masked out with n_new = 0."""
        sched = self.sched
        page = self.page_size
        c, wc = self.chunk_tokens, self.window_pages
        progressed = bool(sched.admit(max_prefill_pages=c // page))
        if not sched.active:
            return progressed
        advancing, decoding = self._plan_chunked()

        if not advancing:
            # pure-decode steady state: speculate when enabled and warm
            if self.spec_k and decoding:
                if self._spec_off > 0:
                    self._spec_off -= 1
                elif self._try_spec_step(decoding):
                    return True
            # steady-state decode: same compiled program as legacy decode
            logits, self.pools = self._decode(
                self.params, self.pools, jnp.asarray(sched.page_table),
                jnp.asarray(self._last_tok), jnp.asarray(sched.lengths))
            self.steps_run += 1
        else:
            b = sched.n_slots
            toks = np.zeros((b, c), np.int32)
            q_start = np.zeros(b, np.int32)
            n_new = np.zeros(b, np.int32)
            windows = np.full((b, wc), SCRATCH_PAGE, np.int32)

            def fill_window(slot, start):
                pidx0 = start // page
                row = sched.page_table[slot]
                take = min(wc, row.shape[0] - pidx0)
                windows[slot, :take] = row[pidx0:pidx0 + take]

            for slot in advancing:
                req = sched.active[slot]
                prog = int(sched.prefill_progress[slot])
                n = min(c, len(req.prompt) - prog)
                toks[slot, :n] = req.prompt[prog:prog + n]
                q_start[slot] = prog
                n_new[slot] = n
                fill_window(slot, prog)
            for slot in decoding:
                start = int(sched.lengths[slot])
                toks[slot, 0] = self._last_tok[slot]
                q_start[slot] = start
                n_new[slot] = 1
                fill_window(slot, start)

            logits, self.pools = self._mixed(
                self.params, self.pools, jnp.asarray(sched.page_table),
                jnp.asarray(windows), jnp.asarray(toks),
                jnp.asarray(q_start), jnp.asarray(n_new))
            self.mixed_steps += 1

        nxt = np.asarray(self._sample(logits))
        for slot in advancing:
            req = sched.active[slot]
            n = int(n_new[slot])
            sched.prefill_progress[slot] += n
            sched.lengths[slot] += n
            self.prefill_tokens += n
            if int(sched.prefill_progress[slot]) == len(req.prompt):
                # prompt fully in cache: logits at its last token yield the
                # first generated token (as legacy prefill does)
                tok = int(nxt[slot])
                req.out.append(tok)
                self._last_tok[slot] = tok
                if self._policies[req.rid].done(req.out):
                    sched.complete(slot)
        for slot in decoding:
            req = sched.active[slot]
            sched.lengths[slot] += 1
            tok = int(nxt[slot])
            req.out.append(tok)
            self._last_tok[slot] = tok
            self.decode_tokens += 1
            if self._policies[req.rid].done(req.out):
                sched.complete(slot)
        return True

    # -- speculative decoding -------------------------------------------------

    def _try_spec_step(self, decoding: List[int]) -> bool:
        """One speculative verify step over the pure-decode lanes: draft up
        to k tokens per lane by prompt lookup, score the k+1-token windows
        read-only in the single jitted verify program, and commit each
        lane's accepted prefix + bonus token through the fused
        quantize-on-write path (rejected suffixes were never written).
        Returns False (caller falls through to the vanilla decode step)
        when the cost-model gate says drafting is too thin to pay for the
        verify — expected extra tokens (drafted total x the running
        acceptance estimate) below spec_gate per lane — so adversarial,
        n-gram-free workloads degrade to plain decode plus a cheap,
        exponentially backed-off host-side lookup.

        Lanes with no usable draft still ride the verify step with
        n_new = 1, which is bit-exact with a vanilla decode write
        (write_chunk with one token == write_token)."""
        sched = self.sched
        page = self.page_size
        cap = self.max_pages_per_seq * page
        cs, wcv = self.spec_tokens, self.spec_window_pages

        drafts: Dict[int, List[int]] = {}
        for slot in decoding:
            req = sched.active[slot]
            length = int(sched.lengths[slot])
            budget_left = self._policies[req.rid].budget - len(req.out)
            # the pending token costs one cache slot and one budget slot;
            # clamp so accept-all can neither overrun the sequence cap nor
            # outlive the stop policy's budget
            room = min(self.spec_k, budget_left - 1, cap - length - 1)
            drafts[slot] = (self._drafter.propose(
                list(req.prompt) + list(req.out), k=room)
                if room >= 1 else [])
        total = sum(len(d) for d in drafts.values())
        if total * self._acc_est < self.spec_gate * len(decoding):
            # expected extra tokens don't cover the verify's cost premium
            # over a plain decode step; after a few consecutive thin steps
            # stop even *drafting* for a while (the host-side lookup is
            # not free at decode-step latencies), doubling the pause up to
            # spec_cooldown so a persistently n-gram-free workload pays an
            # ever-smaller probing tax
            self._gate_misses += 1
            if self._gate_misses >= 2:
                self._spec_off = self._gate_cool
                self._gate_cool = min(self._gate_cool * 2,
                                      self.spec_cooldown)
                self._gate_misses = 0
            return False
        self._gate_misses = 0

        # secure pages for every lane's full window (pending + drafts);
        # growth can preempt lanes (including a drafting lane itself) —
        # replan until a pass allocates without evicting
        try:
            while True:
                evicted = False
                for slot in decoding:
                    if slot not in sched.active:
                        continue
                    target = int(sched.lengths[slot]) + 1 + len(drafts[slot])
                    if sched.grow_to(slot, target):
                        evicted = True
                if not evicted:
                    break
        except RuntimeError:
            # pool too tight for even one lane's window — surplus pages
            # already granted stay with their lanes (reused by later
            # growth, freed on completion); vanilla decode still fits
            # because _plan_chunked grew every lane for one token
            return False
        decoding = [s for s in decoding if s in sched.active]
        if not decoding:
            return False

        b = sched.n_slots
        toks = np.zeros((b, cs), np.int32)
        q_start = np.zeros(b, np.int32)
        n_new = np.zeros(b, np.int32)
        windows = np.full((b, wcv), SCRATCH_PAGE, np.int32)
        for slot in decoding:
            d = drafts[slot]
            start = int(sched.lengths[slot])
            toks[slot, 0] = self._last_tok[slot]
            toks[slot, 1:1 + len(d)] = d
            q_start[slot] = start
            n_new[slot] = 1 + len(d)
            pidx0 = start // page
            row = sched.page_table[slot]
            take = min(wcv, row.shape[0] - pidx0)
            windows[slot, :take] = row[pidx0:pidx0 + take]

        if self.sampler == "greedy":
            key = self._zero_key
        else:
            self._key, key = jax.random.split(self._key)
        emit, acc, self.pools = self._verify(
            self.params, self.pools, jnp.asarray(sched.page_table),
            jnp.asarray(windows), jnp.asarray(toks), jnp.asarray(q_start),
            jnp.asarray(n_new), key)
        emit, acc = np.asarray(emit), np.asarray(acc)
        self.spec_steps += 1

        step_scored = step_accepted = 0
        for slot in decoding:
            req = sched.active[slot]
            a = int(acc[slot])
            self.draft_tokens += int(n_new[slot]) - 1
            self.accepted_tokens += a
            step_scored += int(n_new[slot]) - 1
            step_accepted += a
            new_len = int(q_start[slot]) + 1 + a
            sched.lengths[slot] = new_len
            sched.truncate_to(slot, new_len)
            done = False
            for j in range(a + 1):
                tok = int(emit[slot, j])
                req.out.append(tok)
                self.decode_tokens += 1
                self._last_tok[slot] = tok
                if self._policies[req.rid].done(req.out):
                    done = True
                    break
            if done:
                sched.complete(slot)
        # per-proposal acceptance EMA feeding the gate; the 0.2 floor
        # keeps a cold streak from pinning the gate shut forever (the
        # doubling cooldown, not the EMA, owns long-horizon backoff)
        rate = step_accepted / max(1, step_scored)
        self._acc_est = min(1.0, max(0.2, 0.8 * self._acc_est + 0.2 * rate))
        if rate >= 0.25:
            # a verify that actually paid off restarts the cooldown ladder
            # from the bottom
            self._gate_cool = 2
        else:
            # one that didn't was a false positive from a coincidental
            # n-gram hit — climb the ladder immediately rather than waiting
            # for thin-draft misses, so an adversarial workload's wasted
            # verifies (the costliest false-positive mode) back off just
            # as fast as its wasted drafting
            self._spec_off = self._gate_cool
            self._gate_cool = min(self._gate_cool * 2, self.spec_cooldown)
        return True

    def run(self, prompts: Sequence[Sequence[int]], *,
            mode: str = "slow_think", max_new: int = 32,
            max_steps: int = 100_000) -> ContinuousResult:
        rids = [self.submit(p, mode=mode, max_new=max_new) for p in prompts]
        # fresh speculation heuristics per batch run: leftover cooldown or
        # window state from a previous batch would make identical runs
        # gate differently (submit()/step() callers keep continuous state)
        self._spec_off = self._gate_misses = 0
        self._gate_cool = 2
        self._acc_est = 0.5
        steps0, tokens0 = self.steps_run, self.decode_tokens
        evict0 = self.sched.n_evictions
        mixed0, pf0 = self.mixed_steps, self.prefill_tokens
        hit0 = self.sched.prefix_hit_tokens
        spec0, dr0, acc0 = (self.spec_steps, self.draft_tokens,
                            self.accepted_tokens)
        steps = 0
        while not self.sched.idle:
            progressed = self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("continuous engine exceeded max_steps")
            if not progressed and not self.sched.idle:
                raise RuntimeError("scheduler stalled with pending work")
        reqs = [self._requests[r] for r in rids]
        return ContinuousResult(
            tokens=[r.out for r in reqs],
            modes=[r.mode for r in reqs],
            prompt_lens=[len(r.prompt) for r in reqs],
            steps_run=self.steps_run - steps0,
            decode_tokens=self.decode_tokens - tokens0,
            evictions=self.sched.n_evictions - evict0,
            mixed_steps=self.mixed_steps - mixed0,
            prefill_tokens=self.prefill_tokens - pf0,
            prefix_hit_tokens=self.sched.prefix_hit_tokens - hit0,
            spec_steps=self.spec_steps - spec0,
            draft_tokens=self.draft_tokens - dr0,
            accepted_tokens=self.accepted_tokens - acc0)

"""Prompt-lookup (n-gram) drafting for self-speculative decoding.

No draft model: the drafter proposes the continuation of the most recent
earlier occurrence of the context's trailing n-gram (Saxena-style prompt
lookup). That targets exactly the failure-turned-feature this repo's CoT
study measures (cot.detect_repetition, Figure 4): low-bit reasoning traces
loop, and a looping greedy decode is perfectly predictable from its own
history — every draft token verifies. On non-repetitive output the drafter
finds no match and proposes nothing, so the engine falls back to vanilla
decode steps (see ContinuousBatchingEngine's acceptance-rate cooldown).

Host-side and stateless: `propose` is O((ngram_max - ngram_min) * len)
per call via `bytes.rfind` over the int64-encoded context — single-digit
microseconds at serving context lengths (the engine calls it for every
decoding lane on every non-cooldown step, so per-call constant factors
are a direct decode-throughput tax; a numpy sliding-window compare
measures ~10x slower purely on per-op dispatch overhead).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


class NgramDrafter:
    """Propose up to `k` draft tokens by longest-suffix n-gram lookup.

    Tries suffix n-grams from `ngram_max` down to `ngram_min`; the first
    n with an earlier occurrence wins and the match *closest to the end*
    (most recent, most likely still in-distribution) sets a lag L; drafts
    extrapolate the recurrence x[t] = x[t - L], so a tight loop of period
    L < k still yields k drafts (the copy source rolls into the drafts
    themselves). ngram_min >= 2 keeps spurious single-token matches from
    flooding low-acceptance workloads with doomed drafts.
    """

    def __init__(self, k: int, ngram_max: int = 3, ngram_min: int = 2):
        assert k >= 1 and 1 <= ngram_min <= ngram_max
        self.k = k
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min

    def propose(self, context: Sequence[int], k: int = None) -> List[int]:
        """Draft up to min(k, self.k) tokens continuing `context` (prompt +
        tokens emitted so far, most recent last). Returns [] when no
        trailing n-gram recurs earlier in the context."""
        k = self.k if k is None else min(k, self.k)
        arr = np.asarray(context, dtype=np.int64)
        n_ctx = arr.shape[0]
        if k < 1 or n_ctx < self.ngram_min + 1:
            return []
        # search the byte encoding: rfind is a C substring scan straight to
        # the most recent occurrence; a hit at a non-multiple-of-8 offset
        # is a coincidental byte alignment, not a token match — step the
        # search window back past it (int64 encoding keeps this rare)
        itm = arr.itemsize
        buf = arr[:n_ctx - 1].tobytes()
        for n in range(min(self.ngram_max, n_ctx - 1), self.ngram_min - 1,
                       -1):
            pat = arr[n_ctx - n:].tobytes()
            pos = buf.rfind(pat)
            while pos > 0 and pos % itm:
                pos = buf.rfind(pat, 0, pos + len(pat) - 1)
            if pos < 0 or pos % itm:
                continue
            lag = (n_ctx - n) - pos // itm
            drafts: List[int] = []
            for i in range(k):
                j = n_ctx + i - lag
                drafts.append(int(arr[j]) if j < n_ctx
                              else drafts[j - n_ctx])
            return drafts
        return []

"""Token samplers for the serving engine (greedy / temperature / top-k /
top-p) and rejection-sampling acceptance for speculative decoding.

`speculative_accept` scores a verified draft window: position 0 holds the
last committed token, positions 1..n_new-1 hold drafter proposals, and
`logits[:, i]` is the model's distribution *after* window position i. The
drafter is deterministic (a point mass), so rejection sampling degenerates
to: accept draft d_{i+1} with probability p_i(d_{i+1}); on the first
rejection resample from the residual max(p - q, 0)/Z, which for a point
mass is p with the rejected token zeroed out, renormalized. Greedy is the
zero-temperature limit: accept iff d_{i+1} == argmax p_i, emit argmax —
token-for-token what a vanilla greedy decode loop would produce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def greedy(logits, key=None):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits, key, temp: float = 0.8):
    return jax.random.categorical(key, logits / temp, axis=-1).astype(jnp.int32)


def top_k(logits, key, k: int = 40, temp: float = 0.8):
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(key, vals / temp, axis=-1)
    return jnp.take_along_axis(idx, choice[..., None], -1)[..., 0].astype(jnp.int32)


def filter_top_p(logits, p: float = 0.9):
    """Nucleus filter: keep the smallest set of top tokens whose probability
    mass reaches p (ties at the threshold are all kept); the rest drop to
    NEG_INF. p >= 1 is the identity."""
    if p >= 1.0:
        return logits
    probs = jax.nn.softmax(logits, axis=-1)
    sp = jnp.flip(jnp.sort(probs, axis=-1), axis=-1)
    mass_before = jnp.cumsum(sp, axis=-1) - sp
    keep = mass_before < p          # token enters before the mass reaches p
    thr = jnp.min(jnp.where(keep, sp, 2.0), axis=-1, keepdims=True)
    return jnp.where(probs >= thr, logits, NEG_INF)


def top_p(logits, key, p: float = 0.9, temp: float = 0.8):
    return jax.random.categorical(
        key, filter_top_p(logits / temp, p), axis=-1).astype(jnp.int32)


SAMPLERS = {"greedy": greedy, "temperature": temperature, "top_k": top_k,
            "top_p": top_p}


def speculative_accept(logits, draft, n_new, key, *, mode: str = "greedy",
                       temp: float = 1.0, top_p: float = 1.0):
    """Accept/reject a verified draft window per sequence.

    logits: (B, C, V) f32 — model distribution after each window position;
    draft: (B, C) int32 — column 0 is the last committed token, columns
    1..n_new-1 are drafter proposals (the rest is padding);
    n_new: (B,) valid window tokens (0 = idle lane, 1 = no draft);
    key: PRNG key (unused for mode="greedy").

    Returns (emit (B, C) int32, acc (B,) int32): acc counts the leading
    accepted draft tokens (0 <= acc <= n_new-1); emit[:, j] is the token
    the engine emits at window step j — emit[:, :acc] echoes the accepted
    drafts, emit[:, acc] is the bonus/resample token, and columns past acc
    are garbage the caller must ignore.
    """
    b, c, _ = logits.shape
    i = jnp.arange(c - 1)[None, :]
    in_window = i + 1 < n_new[:, None]                       # draft i+1 valid
    if mode == "greedy":
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)    # (B, C)
        ok = (g[:, :-1] == draft[:, 1:]) & in_window
        acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        return g, acc

    lg = filter_top_p(logits / temp, top_p)
    probs = jax.nn.softmax(lg, axis=-1)                      # (B, C, V)
    k_u, k_full, k_res = jax.random.split(key, 3)
    # deterministic (point-mass) proposal: accept d with probability p(d)
    p_d = jnp.take_along_axis(probs[:, :-1], draft[:, 1:, None], -1)[..., 0]
    u = jax.random.uniform(k_u, (b, c - 1))
    ok = (u < p_d) & in_window
    acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
    # bonus token (all drafts accepted): sample the full distribution;
    # rejection at j: sample the residual — p with the rejected draft
    # token removed, renormalized (guard: an empty residual falls back to
    # the full distribution, which can only happen when p(d) ~ 1 so the
    # rejection branch itself has vanishing probability)
    full = jax.random.categorical(k_full, lg, axis=-1).astype(jnp.int32)
    d_next = jnp.roll(draft, -1, axis=1)                     # draft after j
    res_lg = jnp.where(jax.nn.one_hot(d_next, lg.shape[-1], dtype=bool),
                       NEG_INF, lg)
    res_lg = jnp.where(
        jnp.max(res_lg, axis=-1, keepdims=True) <= NEG_INF, lg, res_lg)
    resid = jax.random.categorical(k_res, res_lg, axis=-1).astype(jnp.int32)
    all_accepted = acc[:, None] >= jnp.maximum(n_new - 1, 0)[:, None]
    at_acc = jnp.where(all_accepted, full, resid)
    j = jnp.arange(c)[None, :]
    emit = jnp.where(j < acc[:, None], d_next, at_acc)
    return emit, acc

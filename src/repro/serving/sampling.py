"""Token samplers for the serving engine (greedy / temperature / top-k)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits, key=None):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits, key, temp: float = 0.8):
    return jax.random.categorical(key, logits / temp, axis=-1).astype(jnp.int32)


def top_k(logits, key, k: int = 40, temp: float = 0.8):
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(key, vals / temp, axis=-1)
    return jnp.take_along_axis(idx, choice[..., None], -1)[..., 0].astype(jnp.int32)


SAMPLERS = {"greedy": greedy, "temperature": temperature, "top_k": top_k}

"""Chain-of-thought reasoning modes + repetitive-generation analysis.

openPangu-Embedded selects its reasoning mode with a directive appended to
the prompt (paper §4.1); we mirror that with reserved directive tokens and
per-mode decode policies:

  slow_think — full reasoning budget (long traces)
  no_think   — condensed budget (short traces)
  auto_think — adaptive: budget switches on prompt complexity (length proxy),
               mirroring the paper's input-dependent switching

The repetition detector implements Figure 4's failure pattern: terminal
output segments consisting of one phrase repeated until termination.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

# Reserved directive token ids (top of the vocab is reserved by convention;
# the synthetic tokenizer never emits ids >= vocab - 8).
MODE_TOKEN_OFFSET = {"slow_think": 1, "auto_think": 2, "no_think": 3}
MODES = tuple(MODE_TOKEN_OFFSET)


@dataclasses.dataclass(frozen=True)
class ModePolicy:
    budget_frac: float      # fraction of max_new_tokens this mode may use
    min_tokens: int = 1


POLICIES = {
    "slow_think": ModePolicy(budget_frac=1.0),
    "no_think": ModePolicy(budget_frac=0.25),
    "auto_think": ModePolicy(budget_frac=-1.0),   # resolved per prompt
}


def mode_token(mode: str, vocab: int) -> int:
    return vocab - MODE_TOKEN_OFFSET[mode]


def apply_mode(prompt: Sequence[int], mode: str, vocab: int) -> List[int]:
    """Append the CoT directive to the prompt (paper §4.1)."""
    return list(prompt) + [mode_token(mode, vocab)]


def budget_for(mode: str, prompt_len: int, max_new: int,
               auto_threshold: int = 32) -> int:
    """Decode budget per mode; auto_think switches slow/no on prompt size."""
    if mode == "auto_think":
        mode = "slow_think" if prompt_len >= auto_threshold else "no_think"
    return max(1, int(max_new * POLICIES[mode].budget_frac))


@dataclasses.dataclass(frozen=True)
class StopPolicy:
    """Per-request stop condition for the continuous-batching scheduler.

    The three think modes collapse to this: a mode is nothing but a prompt
    directive plus a (budget, eos) stop policy fed to the same scheduler."""
    budget: int
    eos_id: Optional[int] = None

    def done(self, generated: Sequence[int]) -> bool:
        if self.eos_id is not None and generated \
                and generated[-1] == self.eos_id:
            return True
        return len(generated) >= self.budget


def policy_for(mode: str, prompt_len: int, max_new: int,
               eos_id: Optional[int] = None,
               auto_threshold: int = 32) -> StopPolicy:
    return StopPolicy(budget_for(mode, prompt_len, max_new, auto_threshold),
                      eos_id)


# ---------------------------------------------------------------------------
# Repetitive generation (Figure 4)
# ---------------------------------------------------------------------------

def detect_repetition(tokens: Sequence[int], max_phrase: int = 8,
                      min_repeats: int = 3, min_cover: int = 12) -> bool:
    """True iff the tail of `tokens` is one phrase (length <= max_phrase)
    repeated >= min_repeats times covering >= min_cover tokens."""
    toks = list(tokens)
    n = len(toks)
    for p in range(1, max_phrase + 1):
        if n < max(p * min_repeats, min_cover):
            continue
        phrase = toks[n - p:]
        reps = 1
        i = n - 2 * p
        while i >= 0 and toks[i:i + p] == phrase:
            reps += 1
            i -= p
        if reps >= min_repeats and reps * p >= min_cover:
            return True
    return False


def repetition_rate(generations) -> float:
    if not generations:
        return 0.0
    return sum(detect_repetition(g) for g in generations) / len(generations)

from repro.serving.engine import ServingEngine, GenerationResult  # noqa
from repro.serving import cot, sampling  # noqa

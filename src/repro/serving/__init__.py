from repro.serving.engine import (ServingEngine, GenerationResult,  # noqa
                                  ContinuousBatchingEngine, ContinuousResult)
from repro.serving import cot, kv_pool, prefix_cache, sampling, \
    scheduler  # noqa

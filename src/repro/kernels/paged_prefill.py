"""Pallas TPU kernel: chunked-prefill attention over paged KV with inline
int8 dequant.

Chunk-shaped attention (q_len = C tokens per sequence) against the paged KV
pool of serving/kv_pool.py
— the read half of the fused quantize-on-write
prefill path: each chunk's K/V has already been quantized into its pages by
`kv_pool.write_chunk`, and this kernel attends causally over everything
written so far (earlier chunks + the in-flight chunk) without ever
materializing a dense cache.

Like paged_attn.py, the page table is a *scalar-prefetch* argument
(pltpu.PrefetchScalarGridSpec): BlockSpec index_maps read it to DMA the
right physical page per (sequence, kv-head, page) grid step, pages stream
HBM -> VMEM, and int8 pages are dequantized in-register against their
per-(page, head) scale. The differences from the decode kernel:

  * the query block is the whole chunk — GQA query heads fold into rows as
    (C * hper, hd), row r belonging to chunk token r // hper;
  * the mask is causal *within* the chunk: row r at absolute position
    q_start[b] + r // hper sees keys kpos <= that position (and
    kpos < kv_lengths[b], which covers slots riding the mixed step with
    fewer than C valid tokens — their extra rows attend a nonempty prefix
    and are discarded by the caller).

Online-softmax state (m, l, acc) lives in VMEM scratch across the page
axis, which is innermost ("arbitrary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant.qtypes import unpack_int4_halves_lastdim
from repro.kernels import ops, tpu_compiler_params
from repro.kernels.ref import (  # noqa: F401  (oracles)
    paged_prefill_attention_ref, paged_verify_attention_ref)

NEG_INF = -1e30


def _kernel(pt_ref, qstart_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
            o_ref, m_ref, l_ref, acc_ref, *, page: int, hper: int,
            scale: float, quantized: bool, packed: bool):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    klen = len_ref[b]
    q0 = qstart_ref[b]

    @pl.when(j * page < klen)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (C*hper, hd)
        k = k_ref[0, :, 0, :]                            # (page, hd[/2])
        v = v_ref[0, :, 0, :]
        if packed:                 # in-register nibble unpack: (page, hd)
            k = unpack_int4_halves_lastdim(k)
            v = unpack_int4_halves_lastdim(v)
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // hper
        s = jnp.where((kpos <= qpos) & (kpos < klen), s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q, k_pages, v_pages, k_scale, v_scale,
                            page_table, q_start, kv_lengths, *,
                            interpret: bool = False):
    """q: (B, C, nq, hd) chunk queries at positions q_start[b] + i;
    k_pages/v_pages: (P, page, nkv, hd) int8/float or (P, page, nkv, hd//2)
    uint8 packed int4; k_scale/v_scale: (P, nkv) f32 (quantized pools) or
    None; page_table: (B, W) physical ids; q_start: (B,); kv_lengths: (B,)
    valid keys (>= 1). Returns (B, C, nq, hd) in q.dtype. Same contract as
    `ref.paged_prefill_attention_ref`."""
    b, c, nq, hd = q.shape
    n_pages, page, nkv, hd_kv = k_pages.shape      # hd_kv = hd//2 if packed
    w = page_table.shape[1]
    hper = nq // nkv
    assert nq == nkv * hper, (nq, nkv)
    k_scale, v_scale, quantized, packed = ops.paged_pool_scales(
        k_pages, k_scale, v_scale)

    # rows: chunk-major, heads-within-token minor -> row r = token r // hper
    qg = (q.reshape(b, c, nkv, hper, hd).transpose(0, 2, 1, 3, 4)
          .reshape(b, nkv, c * hper, hd))
    pt_flat = page_table.reshape(-1).astype(jnp.int32)

    kern = functools.partial(_kernel, page=page, hper=hper,
                             scale=1.0 / (hd ** 0.5), quantized=quantized,
                             packed=packed)
    grid = (b, nkv, w)
    page_spec, scale_spec = ops.paged_block_specs(w, page, hd_kv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c * hper, hd), lambda bi, h, j, pt, qs, lens:
                         (bi, h, 0, 0)),
            page_spec,
            page_spec,
            scale_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, c * hper, hd),
                               lambda bi, h, j, pt, qs, lens: (bi, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((c * hper, 1), jnp.float32),
                        pltpu.VMEM((c * hper, 1), jnp.float32),
                        pltpu.VMEM((c * hper, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, c * hper, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pt_flat, q_start.astype(jnp.int32), kv_lengths.astype(jnp.int32),
      qg, k_pages, v_pages, k_scale, v_scale)
    return (out.reshape(b, nkv, c, hper, hd).transpose(0, 2, 1, 3, 4)
            .reshape(b, c, nq, hd))


def paged_verify_attention(q, k_pages, v_pages, k_scale, v_scale,
                           page_table, q_start, n_new, k_win, v_win, *,
                           interpret: bool = False):
    """Multi-query-per-sequence decode variant for speculative verify:
    causal-masked chunk attention with the valid-key horizon pinned to the
    draft window's end (kv_lengths = q_start + n_new) and the window's raw
    K/V (k_win/v_win) spliced over the gathered keys, so the pool is never
    written for a draft that may be rejected. C = k+1 need not be
    page-aligned (window-sizing via kv_pool.verify_window_pages, not
    chunk_window_pages).

    The streaming Pallas chunk kernel reads pages only; feeding it the
    in-flight window would need an extra VMEM operand (ROADMAP), so the
    verify step currently runs the XLA gather path on every backend —
    identical math, and the per-step cost is one page-table gather, same
    as the kernel's contract. Contract: `ref.paged_verify_attention_ref`."""
    del interpret  # no Pallas variant yet; XLA gather path on all backends
    return paged_verify_attention_ref(q, k_pages, v_pages, k_scale, v_scale,
                                      page_table, q_start, n_new,
                                      k_win, v_win)

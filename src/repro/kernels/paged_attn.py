"""Pallas TPU kernel: paged decode attention with inline int8 dequant.

Decode-shaped attention (q_len=1 per sequence) against the paged KV pool of
serving/kv_pool.py. The page table is a *scalar-prefetch* argument
(pltpu.PrefetchScalarGridSpec): BlockSpec index_maps read it to DMA the
right physical page for each (sequence, kv-head, page) grid step, so the
gather never materializes in HBM — pages stream HBM -> VMEM directly and
int8 pages are dequantized in-register against their per-(page, head)
scale. Online-softmax state (m, l, acc) lives in VMEM scratch across the
page axis, exactly like flash_attn.py's KV-block loop.

Grid: (B, n_kv_heads, n_pages) with pages innermost ("arbitrary" — the
accumulators carry across it). GQA query heads of one KV head are processed
together as a (hper, hd) block. Sequences shorter than the page-table width
mask dead slots by position; fully-dead pages are skipped via pl.when (the
DMA of the scratch page they point at is wasted bandwidth, not wrong).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant.qtypes import unpack_int4_halves_lastdim
from repro.kernels import ops, tpu_compiler_params

NEG_INF = -1e30


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, scale: float,
            quantized: bool, packed: bool):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    klen = len_ref[b]

    @pl.when(j * page < klen)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (hper, hd)
        k = k_ref[0, :, 0, :]                                # (page, hd[/2])
        v = v_ref[0, :, 0, :]
        if packed:                 # in-register nibble unpack: (page, hd)
            k = unpack_int4_halves_lastdim(k)
            v = unpack_int4_halves_lastdim(v)
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = j * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < klen, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, k_scale, v_scale,
                           page_table, kv_lengths, *,
                           interpret: bool = False):
    """q: (B, nq, hd); k_pages/v_pages: (P, page, nkv, hd) int8/float or
    (P, page, nkv, hd//2) uint8 packed int4; k_scale/v_scale: (P, nkv) f32
    (quantized pools) or None; page_table: (B, W) physical page ids;
    kv_lengths: (B,) valid keys (>= 1). Returns (B, nq, hd) in q.dtype."""
    b, nq, hd = q.shape
    n_pages, page, nkv, hd_kv = k_pages.shape      # hd_kv = hd//2 if packed
    w = page_table.shape[1]
    hper = nq // nkv
    assert nq == nkv * hper, (nq, nkv)
    k_scale, v_scale, quantized, packed = ops.paged_pool_scales(
        k_pages, k_scale, v_scale)

    qg = q.reshape(b, nkv, hper, hd)
    pt_flat = page_table.reshape(-1).astype(jnp.int32)

    kern = functools.partial(_kernel, page=page, scale=1.0 / (hd ** 0.5),
                             quantized=quantized, packed=packed)
    grid = (b, nkv, w)
    page_spec, scale_spec = ops.paged_block_specs(w, page, hd_kv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hper, hd), lambda bi, h, j, pt, lens:
                         (bi, h, 0, 0)),
            page_spec,
            page_spec,
            scale_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, hper, hd), lambda bi, h, j, pt, lens:
                               (bi, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((hper, 1), jnp.float32),
                        pltpu.VMEM((hper, 1), jnp.float32),
                        pltpu.VMEM((hper, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, hper, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pt_flat, kv_lengths.astype(jnp.int32), qg, k_pages, v_pages,
      k_scale, v_scale)
    return out.reshape(b, nq, hd)


def paged_decode_attention_ref(q, k_pages, v_pages, k_scale, v_scale,
                               page_table, kv_lengths):
    """Pure-jnp oracle (and the XLA serving path on CPU): gather pages,
    dequantize, masked softmax. Same contract as the kernel."""
    b, nq, hd = q.shape
    _, page, nkv, _ = k_pages.shape
    w = page_table.shape[1]
    hper = nq // nkv

    def read(pages, scales):
        g = pages[page_table]                          # (B, W, page, nkv, hd)
        if g.dtype == jnp.uint8:                       # packed int4 pages
            g = unpack_int4_halves_lastdim(g)
        g = g.astype(jnp.float32)
        if pages.dtype in (jnp.int8, jnp.uint8):
            g = g * scales[page_table][:, :, None, :, None]
        return g.reshape(b, w * page, nkv, hd)

    k = read(k_pages, k_scale)
    v = read(v_pages, v_scale)
    # GQA via an explicit group axis: materializing jnp.repeat'ed K/V
    # costs ~2x the attention itself on the XLA CPU path; the grouped
    # contraction is bitwise identical (same per-(query, key) dot)
    qg = (q.reshape(b, nkv, hper, hd).astype(jnp.float32) / (hd ** 0.5))
    scores = jnp.einsum("bgph,btgh->bgpt", qg, k)
    mask = (jnp.arange(w * page)[None, None, None, :]
            < kv_lengths[:, None, None, None])
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgpt,btgh->bgph", probs, v)
    return out.reshape(b, nq, hd).astype(q.dtype)

"""Pallas TPU kernel: W4A8 GEMM — packed int4 weights unpacked in VMEM.

TPU v5e has no int4 MXU path, so (DESIGN.md §2) the 4-bit win is taken as a
*bandwidth/storage* win: weights live in HBM as two signed nibbles per int8
byte in the grouped-halves layout (`qtypes.pack_int4_halves`) and each
(bk, bn) weight tile is expanded to int8 inside VMEM right before the MXU
dot — one arithmetic-shift pair + a concatenation, no row interleave.

Per-group dequantization: the K grid dimension steps one quantization group
(bk == group_size) at a time; each group's int32 partial product is scaled
by its (1, bn) float32 group scale and accumulated into a float32 VMEM
accumulator, so cross-group accumulation is exact in fp32 (the contract
`ref.w4a8_matmul_ref` checks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(x_ref, wp_ref, xs_ref, gs_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Unpack the (g/2, bn) packed tile -> (g, bn) int8 (values in [-8, 7]).
    packed = wp_ref[...]
    lo = jnp.right_shift(jnp.left_shift(packed, 4), 4)
    hi = jnp.right_shift(packed, 4)
    w_tile = jnp.concatenate([lo, hi], axis=0)          # 'halves' layout

    part = jnp.dot(x_ref[...], w_tile, preferred_element_type=jnp.int32)
    acc_ref[...] += part.astype(jnp.float32) * gs_ref[...]

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[...] = (acc_ref[...] * xs_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group_size", "bm", "bn",
                                             "out_dtype", "interpret"))
def w4a8_matmul(x_q: jax.Array, w_packed: jax.Array,
                x_scale: jax.Array, w_group_scale: jax.Array,
                *, group_size: int = 128, bm: int = 256, bn: int = 256,
                out_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """x_q (M,K) int8; w_packed (K//2,N) int8 'halves'; x_scale (M,1) f32;
    w_group_scale (K//G, N) f32. K must be a multiple of group_size."""
    m, k = x_q.shape
    kp, n = w_packed.shape
    assert kp * 2 == k, (x_q.shape, w_packed.shape)
    g = group_size
    assert k % g == 0 and w_group_scale.shape == (k // g, n)
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    grid = (m // bm, n // bn, k // g)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, g), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((g // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_packed, x_scale, w_group_scale)

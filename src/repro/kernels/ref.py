"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` function defines the exact numerical contract its kernel must
match (tests assert allclose between `interpret=True` kernel execution and
these references across shape/dtype sweeps).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qtypes
from repro.core.quant.hadamard import block_hadamard_matmul


# ---------------------------------------------------------------------------
# INT8 (W8A8) GEMM with fused dequant epilogue
# ---------------------------------------------------------------------------

def int8_matmul_ref(x_q: jax.Array, w_q: jax.Array,
                    x_scale: jax.Array, w_scale: jax.Array,
                    out_dtype=jnp.float32) -> jax.Array:
    """(M,K) int8 @ (K,N) int8 -> int32 accum -> * x_scale (M,1) * w_scale (1,N)."""
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


# ---------------------------------------------------------------------------
# W4A8 GEMM: packed int4 weights, per-group scales, int8 activations
# ---------------------------------------------------------------------------

def w4a8_matmul_ref(x_q: jax.Array, w_packed: jax.Array,
                    x_scale: jax.Array, w_group_scale: jax.Array,
                    group_size: int, out_dtype=jnp.float32) -> jax.Array:
    """x_q: (M,K) int8; w_packed: (K//2,N) int8 in 'halves' layout;
    w_group_scale: (K//G, N) f32; x_scale: (M,1) f32.

    Contract: int32 accumulation within each K-group, float32 across groups
    (matches the kernel's per-group dequant epilogue).
    """
    k = x_q.shape[1]
    n = w_packed.shape[1]
    g = group_size
    w_q = qtypes.unpack_int4_halves(w_packed, g)          # (K, N) int4-valued
    xg = x_q.reshape(x_q.shape[0], k // g, g)
    wg = w_q.reshape(k // g, g, n)
    # int32 accumulate per group
    acc_g = jnp.einsum("mgk,gkn->mgn", xg.astype(jnp.int32), wg.astype(jnp.int32))
    out = jnp.einsum("mgn,gn->mn", acc_g.astype(jnp.float32),
                     w_group_scale.astype(jnp.float32))
    return (out * x_scale).astype(out_dtype)


# ---------------------------------------------------------------------------
# Dynamic per-token activation quantization (optionally fused smooth / FWHT)
# ---------------------------------------------------------------------------

def quantize_act_ref(x: jax.Array,
                     smooth: Optional[jax.Array] = None,
                     hadamard_block: int = 0):
    """x: (M, K) float -> (q int8 (M,K), scale f32 (M,1)).

    Pipeline (paper §3.2): X <- X / s  (SmoothQuant), X <- X H (rotation),
    then symmetric per-token quantization (Eq. 2).
    """
    t = x.astype(jnp.float32)
    if smooth is not None:
        t = t / smooth.astype(jnp.float32)
    if hadamard_block:
        t = block_hadamard_matmul(t, hadamard_block)
    q, scale = qtypes.quantize_act(t, bits=8, granularity="per_token")
    return q, scale


def fused_rmsnorm_quant_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
                            smooth: Optional[jax.Array] = None):
    """Beyond-paper fused epilogue: RMSNorm -> (smooth) -> per-token quant."""
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    t = xf / rms * gamma.astype(jnp.float32)
    if smooth is not None:
        t = t / smooth.astype(jnp.float32)
    q, scale = qtypes.quantize_act(t, bits=8, granularity="per_token")
    return q, scale


# ---------------------------------------------------------------------------
# Block Walsh-Hadamard transform
# ---------------------------------------------------------------------------

def hadamard_ref(x: jax.Array, block: int = 128) -> jax.Array:
    return block_hadamard_matmul(x, block)


# ---------------------------------------------------------------------------
# INT8 KV-cache attention helpers (beyond-paper: quantized KV)
# ---------------------------------------------------------------------------

def kv_dequant_ref(k_q: jax.Array, k_scale: jax.Array) -> jax.Array:
    """Per (token, head) scales: k_q (..., S, H, D) int8, k_scale (..., S, H, 1)."""
    return k_q.astype(jnp.float32) * k_scale


# ---------------------------------------------------------------------------
# Chunked-prefill attention over paged KV (beyond-paper: batched prefill)
# ---------------------------------------------------------------------------

PAGED_NEG_INF = -1e30


def paged_prefill_attention_ref(q, k_pages, v_pages, k_scale, v_scale,
                                page_table, q_start, kv_lengths):
    """Chunk-query causal attention against the paged (optionally int8) KV
    pool — the XLA serving path and the contract the Pallas kernel in
    `paged_prefill.py` is pinned to.

    q: (B, C, nq, hd) chunk queries, query i at absolute position
    q_start[b] + i; k_pages/v_pages: (P, page, nkv, hd) int8 or float;
    k_scale/v_scale: (P, nkv) f32 per-(page, head) scales (int8 pools) or
    None; page_table: (B, W) physical page ids; q_start: (B,);
    kv_lengths: (B,) valid keys including the in-flight chunk (>= 1).
    Query i sees keys at kpos <= q_start[b] + i with kpos < kv_lengths[b].
    Returns (B, C, nq, hd) in q.dtype.
    """
    return _chunk_attend(q, _read_pages(k_pages, k_scale, page_table),
                         _read_pages(v_pages, v_scale, page_table),
                         q_start, kv_lengths)


def _read_pages(pages, scales, page_table):
    """Gather + dequantize a page table's worth of KV: (B, W*page, nkv, hd)
    f32. uint8 pages are packed int4 (two nibbles per byte along head_dim,
    grouped halves) — shift-unpacked before the scale is applied, so hd
    here is twice the stored last dim."""
    b, w = page_table.shape
    g = pages[page_table]                              # (B, W, page, nkv, .)
    if g.dtype == jnp.uint8:
        g = qtypes.unpack_int4_halves_lastdim(g)
    g = g.astype(jnp.float32)
    if pages.dtype in (jnp.int8, jnp.uint8):
        g = g * scales[page_table][:, :, None, :, None]
    _, _, page, nkv, hd = g.shape
    return g.reshape(b, w * page, nkv, hd)


def _chunk_attend(q, k, v, q_start, kv_lengths):
    """Causal chunk-query attention over dense per-sequence keys: query i
    (absolute position q_start[b] + i) sees kpos <= q_start[b] + i with
    kpos < kv_lengths[b].

    GQA is expressed with an explicit group axis (einsum broadcasts the
    shared K/V head over its `hper` queries) rather than jnp.repeat —
    materializing the repeated K/V costs ~2x the whole attention on the
    XLA CPU path, and the grouped contraction is bitwise identical (the
    per-(query, key) dot over hd is unchanged)."""
    b, c, nq, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    hper = nq // nkv
    qg = q.reshape(b, c, nkv, hper, hd).astype(jnp.float32) / (hd ** 0.5)
    scores = jnp.einsum("bcgph,btgh->bgpct", qg, k)
    kpos = jnp.arange(t)[None, None, None, None, :]
    qpos = (q_start[:, None] + jnp.arange(c)[None, :])[:, None, None, :, None]
    mask = (kpos <= qpos) & (kpos < kv_lengths[:, None, None, None, None])
    scores = jnp.where(mask, scores, PAGED_NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgpct,btgh->bcgph", probs, v)
    return out.reshape(b, c, nq, hd).astype(q.dtype)


def paged_verify_attention_ref(q, k_pages, v_pages, k_scale, v_scale,
                               page_table, q_start, n_new, k_win, v_win):
    """Multi-query-per-sequence decode (speculative verify), read-only on
    the pool: the C-token draft window's raw K/V projections (k_win/v_win,
    (B, C, nkv, hd)) are spliced over the gathered past keys at positions
    q_start..q_start+C-1 instead of being written into pages first, so a
    rejected draft never touches the pool. The valid-key horizon is the
    window end — kv_lengths = q_start + n_new — and the causal chunk mask
    handles the intra-window triangle; C need not be page-aligned (k+1
    draft tokens); max(.., 1) keeps idle lanes (n_new == 0) finite so
    their garbage rows still softmax over a nonempty prefix.

    For float pools the splice is bit-identical to a write + paged read
    (the page round trip is a no-op cast); for int8 pools the window skips
    one quantize-dequantize round trip, so verify logits can differ from
    the written-then-read chain within quantization noise."""
    c = q.shape[1]
    page = k_pages.shape[1]
    kv_lengths = jnp.maximum(q_start + n_new, 1)
    # extend the *table* (not the gathered data) by enough pages that the
    # per-batch splice never clamps near the end of a full sequence
    # (q_start <= W*page - 1 by the scheduler's capacity invariant): the
    # pad columns only ever hold window rows >= n_new, which kv_lengths
    # masks off, so any valid page id works as filler
    pad = -(-max(c - 1, 1) // page)
    ext = jnp.concatenate([page_table] + [page_table[:, :1]] * pad, axis=1)

    def inject(pages, scales, wnd):
        dense = _read_pages(pages, scales, ext)
        return jax.vmap(
            lambda db, wb, s: jax.lax.dynamic_update_slice(
                db, wb.astype(db.dtype), (s, 0, 0)))(dense, wnd, q_start)

    k = inject(k_pages, k_scale, k_win)
    v = inject(v_pages, v_scale, v_win)
    return _chunk_attend(q, k, v, q_start, kv_lengths)

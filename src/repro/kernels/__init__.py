"""Pallas TPU kernels for the low-bit inference framework.

Modules: int8_gemm, w4a8_gemm, quantize_act, hadamard, paged_attn (kernels);
ops (jit'd wrappers + dispatch); ref (pure-jnp oracles).

Version-compat shim: the TPU compiler-params dataclass was renamed across
JAX releases (`TPUCompilerParams` in 0.4.x, `CompilerParams` in newer
pallas). Kernels build their params through `tpu_compiler_params` so both
spellings work against whichever JAX is installed.
"""
from jax.experimental.pallas import tpu as _pltpu

_COMPILER_PARAMS_CLS = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Construct pltpu compiler params under either JAX spelling."""
    return _COMPILER_PARAMS_CLS(**kwargs)

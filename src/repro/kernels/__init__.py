"""Pallas TPU kernels for the low-bit inference framework.

Modules: int8_gemm, w4a8_gemm, quantize_act, hadamard (kernels);
ops (jit'd wrappers + dispatch); ref (pure-jnp oracles).
"""

"""Pallas TPU kernel: standalone block Walsh-Hadamard transform.

Online activation rotation (paper Eq. 4, QuaRot-style) for sites where the
rotation is *not* fused into the quantization kernel (e.g. rotating values
feeding an unquantized op). Butterfly runs entirely in VMEM registers:
log2(block) add/sub sweeps, O(K log b) instead of a (K, K) matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tpu_compiler_params
from repro.kernels.quantize_act import _fwht, _pick_bm


def _kernel_factory(block: int):
    def kernel(x_ref, o_ref):
        t = x_ref[...].astype(jnp.float32)
        o_ref[...] = _fwht(t, block).astype(o_ref.dtype)
    return kernel


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def block_hadamard(x: jax.Array, *, block: int = 128,
                   interpret: bool = False) -> jax.Array:
    """x (M, K) -> X H_block (same shape/dtype). K % block == 0."""
    m, k = x.shape
    assert k % block == 0, (k, block)
    bm = _pick_bm(m, k)
    return pl.pallas_call(
        _kernel_factory(block),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)

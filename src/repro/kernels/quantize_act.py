"""Pallas TPU kernel: dynamic per-token activation quantization.

One VMEM pass produces (int8 values, per-token f32 scales) — the runtime
half of W8A8/W4A8. Optional fusions (compile-time flags), mirroring the
paper's "no intermediate format conversion" principle by keeping the whole
pre-GEMM pipeline in one kernel:

  * SmoothQuant:  X <- X / s        (per-channel diagonal, Eq. 3)
  * Hadamard:     X <- X H_block    (block-FWHT butterfly in VMEM, Eq. 4)
  * RMSNorm:      X <- rmsnorm(X)*gamma  (beyond-paper fused epilogue —
                  QServe-style; removes a full HBM round-trip per layer)

Row-blocked: grid over M, full K resident per block (per-token absmax needs
the whole feature dim; block height auto-sized to the VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant.qtypes import qmax, qmin, scale_denom
from repro.kernels import tpu_compiler_params

_QMAX = float(qmax(8))
_QMIN = float(qmin(8))              # canonical narrow symmetric range
_SCALE_DENOM = scale_denom(8)       # paper Eq. 2: s = 2*max|X| / (2^8 - 1)
_VMEM_BUDGET = 6 * 1024 * 1024  # bytes of f32 working set per block


def _fwht(t: jax.Array, block: int) -> jax.Array:
    """In-register block FWHT along the last axis. t: (bm, K) f32."""
    bm, k = t.shape
    t = t.reshape(bm, k // block, block)
    h = 1
    while h < block:
        t = t.reshape(bm, k // block, block // (2 * h), 2, h)
        a = t[..., 0, :]
        b = t[..., 1, :]
        t = jnp.concatenate([a + b, a - b], axis=-1)
        h *= 2
    return t.reshape(bm, k) * (1.0 / jnp.sqrt(jnp.float32(block)))


def _make_kernel(has_smooth: bool, hadamard_block: int, has_norm: bool,
                 eps: float):
    def kernel(*refs):
        idx = 0
        x_ref = refs[idx]; idx += 1
        s_ref = refs[idx] if has_smooth else None
        idx += int(has_smooth)
        g_ref = refs[idx] if has_norm else None
        idx += int(has_norm)
        q_ref, scale_ref = refs[idx], refs[idx + 1]

        t = x_ref[...].astype(jnp.float32)
        if has_norm:
            rms = jnp.sqrt(jnp.mean(t * t, axis=-1, keepdims=True) + eps)
            t = t / rms * g_ref[...].astype(jnp.float32)
        if has_smooth:
            t = t / s_ref[...].astype(jnp.float32)
        if hadamard_block:
            t = _fwht(t, hadamard_block)
        absmax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
        scale = jnp.maximum(2.0 * absmax / _SCALE_DENOM, 1e-8)
        q = jnp.clip(jnp.round(t / scale), _QMIN, _QMAX)
        q_ref[...] = q.astype(jnp.int8)
        scale_ref[...] = scale

    return kernel


def _pick_bm(m: int, k: int) -> int:
    bm = max(8, _VMEM_BUDGET // (k * 4))
    bm = 1 << (bm.bit_length() - 1)          # round down to a power of two
    bm = min(bm, 512)
    while m % bm != 0:
        bm //= 2
    return max(bm, 1)


@functools.partial(jax.jit, static_argnames=("hadamard_block", "rmsnorm_eps",
                                             "interpret"))
def quantize_act_dynamic(x: jax.Array, smooth=None, gamma=None, *,
                         hadamard_block: int = 0,
                         rmsnorm_eps: float = 0.0,
                         interpret: bool = False):
    """x (M,K) float -> (q (M,K) int8, scale (M,1) f32).

    smooth: optional (K,) f32 divisor; gamma: optional (K,) RMSNorm gain
    (rmsnorm_eps > 0 enables the fused-norm path).
    """
    m, k = x.shape
    has_smooth = smooth is not None
    has_norm = gamma is not None
    bm = _pick_bm(m, k)

    in_specs = [pl.BlockSpec((bm, k), lambda i: (i, 0))]
    args = [x]
    if has_smooth:
        in_specs.append(pl.BlockSpec((1, k), lambda i: (0, 0)))
        args.append(smooth.reshape(1, k))
    if has_norm:
        assert rmsnorm_eps > 0.0
        in_specs.append(pl.BlockSpec((1, k), lambda i: (0, 0)))
        args.append(gamma.reshape(1, k))

    q, scale = pl.pallas_call(
        _make_kernel(has_smooth, hadamard_block, has_norm, rmsnorm_eps),
        grid=(m // bm,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, k), jnp.int8),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    return q, scale

"""Pallas TPU kernel: W8A8 int8 GEMM with fused dequantization epilogue.

The paper's framework contribution (§3.1) is native low-bit GEMM on the
Atlas A2 cube unit with dequant folded into the epilogue so no intermediate
format conversions occur. TPU adaptation: int8×int8→int32 on the MXU
(`preferred_element_type=int32`), int32 accumulator held in a VMEM scratch
tile across the K grid dimension, per-token (M) and per-channel (N) float32
scales applied on the accumulator in the final K step before writeback.

Tiling: grid (M/bm, N/bn, K/bk). Blocks are MXU-aligned (multiples of 128 on
the minor dims; int8 native tile is (32, 128) so bk,bn multiples of 128 and
bm multiples of 32 keep layouts packed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out = acc * xs_ref[...] * ws_ref[...]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def int8_matmul(x_q: jax.Array, w_q: jax.Array,
                x_scale: jax.Array, w_scale: jax.Array,
                *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK, out_dtype=jnp.float32,
                interpret: bool = False) -> jax.Array:
    """x_q (M,K) int8, w_q (K,N) int8, x_scale (M,1) f32, w_scale (1,N) f32.

    Requires M % bm == K % bk == N % bn == 0 (ops.py pads + dispatches).
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)

"""Public jit'd wrappers over the Pallas kernels with XLA fallbacks.

Implementation dispatch:
  * "pallas"           — compiled TPU kernels (the deployment path)
  * "pallas_interpret" — same kernel bodies executed in interpret mode
                         (CPU correctness validation; used by tests)
  * "xla"              — plain-jnp int8 HLO path. Numerically identical
                         contract (see ref.py); used on CPU and for the
                         multi-pod dry-run, where XLA's int8 dot carries the
                         cost_analysis FLOPs/bytes for the roofline.
  * "auto"             — "pallas" on TPU backends, else "xla".

Wrappers flatten leading batch dims to M, pad M to tile multiples, and fall
back to "xla" whenever a dim is not kernel-aligned (K, N multiples of 128),
so callers never have to think about tiling.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import int8_gemm, w4a8_gemm, quantize_act, hadamard, ref

_DEFAULT_IMPL = "auto"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("auto", "pallas", "pallas_interpret", "xla")
    _DEFAULT_IMPL = impl


@contextlib.contextmanager
def default_impl(impl: str):
    prev = _DEFAULT_IMPL
    set_default_impl(impl)
    try:
        yield
    finally:
        set_default_impl(prev)


def resolve_impl(impl: Optional[str]) -> str:
    impl = impl or _DEFAULT_IMPL
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _aligned(*dims_mults) -> bool:
    return all(d % m == 0 for d, m in dims_mults)


def _flatten_m(x: jax.Array):
    """(..., K) -> ((M, K), unflatten)"""
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, x.shape[-1])
    return x2, lead


def _pad_m(x: jax.Array, mult: int):
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


# ---------------------------------------------------------------------------
# Paged-attention kernel plumbing (shared by paged_attn / paged_prefill)
# ---------------------------------------------------------------------------

def paged_pool_scales(k_pages, k_scale, v_scale):
    """Normalize per-(page, head) scale inputs for the paged kernels:
    quantized pools pass their real scales through; float pools get dummy
    all-ones scales so one kernel signature serves all dtypes. `packed`
    flags uint8 nibble pages (kv_bits=4) whose last dim is head_dim // 2 —
    kernel bodies must shift-unpack before dequantizing. Returns
    (k_scale, v_scale, quantized, packed)."""
    quantized = k_pages.dtype in (jnp.int8, jnp.uint8)
    packed = k_pages.dtype == jnp.uint8
    if not quantized:
        n_pages, _, nkv, _ = k_pages.shape
        ones = jnp.ones((n_pages, nkv), jnp.float32)
        k_scale, v_scale = ones, ones
    return k_scale, v_scale, quantized, packed


def paged_block_specs(w: int, page: int, hd: int):
    """(page-data, scale) BlockSpecs shared by the paged kernels on the
    (B, n_kv_heads, W) grid: index_maps dereference the scalar-prefetched
    flat page table `pt`; `*_` absorbs the kernel-specific trailing
    prefetch refs (lengths, q_start, ...)."""
    def page_map(bi, h, j, pt, *_):
        return (pt[bi * w + j], 0, h, 0)

    def scale_map(bi, h, j, pt, *_):
        return (pt[bi * w + j], h)

    return (pl.BlockSpec((1, page, 1, hd), page_map),
            pl.BlockSpec((1, 1), scale_map))


# ---------------------------------------------------------------------------
# INT8 GEMM
# ---------------------------------------------------------------------------

def int8_matmul(x_q, w_q, x_scale, w_scale, *, out_dtype=jnp.float32,
                impl: Optional[str] = None):
    """x_q (..., K) int8 @ w_q (K, N) int8 with fused dequant -> (..., N)."""
    impl = resolve_impl(impl)
    x2, lead = _flatten_m(x_q)
    s2 = x_scale.reshape(x2.shape[0], 1)
    k, n = w_q.shape
    ws = w_scale.reshape(1, n)
    if impl == "xla" or not _aligned((k, 128), (n, 128)):
        out = ref.int8_matmul_ref(x2, w_q, s2, ws, out_dtype)
    else:
        interp = impl == "pallas_interpret"
        xp, m0 = _pad_m(x2, 32)
        sp, _ = _pad_m(s2, 32)
        bm = min(int8_gemm.DEFAULT_BM, max(32, xp.shape[0]))
        while xp.shape[0] % bm:
            bm //= 2
        out = int8_gemm.int8_matmul(xp, w_q, sp, ws, bm=bm,
                                    out_dtype=out_dtype, interpret=interp)
        out = out[:m0]
    return out.reshape(lead + (n,))


# ---------------------------------------------------------------------------
# W4A8 GEMM
# ---------------------------------------------------------------------------

def w4a8_matmul(x_q, w_packed, x_scale, w_group_scale, *, group_size: int,
                out_dtype=jnp.float32, impl: Optional[str] = None):
    impl = resolve_impl(impl)
    x2, lead = _flatten_m(x_q)
    s2 = x_scale.reshape(x2.shape[0], 1)
    kp, n = w_packed.shape
    k = kp * 2
    if impl == "xla" or not _aligned((k, group_size), (n, 128)) \
            or group_size % 2:
        out = ref.w4a8_matmul_ref(x2, w_packed, s2, w_group_scale,
                                  group_size, out_dtype)
    else:
        interp = impl == "pallas_interpret"
        xp, m0 = _pad_m(x2, 32)
        sp, _ = _pad_m(s2, 32)
        bm = min(256, max(32, xp.shape[0]))
        while xp.shape[0] % bm:
            bm //= 2
        out = w4a8_gemm.w4a8_matmul(xp, w_packed, sp, w_group_scale,
                                    group_size=group_size, bm=bm,
                                    out_dtype=out_dtype, interpret=interp)
        out = out[:m0]
    return out.reshape(lead + (n,))


# ---------------------------------------------------------------------------
# Dynamic activation quantization (+ optional fused smooth / FWHT / RMSNorm)
# ---------------------------------------------------------------------------

def quantize_act_dynamic(x, smooth=None, gamma=None, *,
                         hadamard_block: int = 0, rmsnorm_eps: float = 0.0,
                         impl: Optional[str] = None):
    """x (..., K) -> (q int8 (..., K), scale f32 (..., 1))."""
    impl = resolve_impl(impl)
    x2, lead = _flatten_m(x)
    k = x2.shape[1]
    pallas_ok = _aligned((k, 128)) and (hadamard_block == 0
                                        or k % hadamard_block == 0)
    if impl == "xla" or not pallas_ok:
        if rmsnorm_eps > 0.0 and gamma is not None:
            q, s = ref.fused_rmsnorm_quant_ref(x2, gamma, rmsnorm_eps, smooth)
            if hadamard_block:
                raise NotImplementedError("norm+hadamard fusion unused")
        else:
            q, s = ref.quantize_act_ref(x2, smooth, hadamard_block)
    else:
        interp = impl == "pallas_interpret"
        xp, m0 = _pad_m(x2, 8)
        q, s = quantize_act.quantize_act_dynamic(
            xp, smooth, gamma, hadamard_block=hadamard_block,
            rmsnorm_eps=rmsnorm_eps, interpret=interp)
        q, s = q[:m0], s[:m0]
    return q.reshape(lead + (k,)), s.reshape(lead + (1,))


# ---------------------------------------------------------------------------
# Block Hadamard
# ---------------------------------------------------------------------------

def block_hadamard(x, *, block: int = 128, impl: Optional[str] = None):
    impl = resolve_impl(impl)
    x2, lead = _flatten_m(x)
    k = x2.shape[1]
    if impl == "xla" or k % block != 0:
        out = ref.hadamard_ref(x2, block)
    else:
        interp = impl == "pallas_interpret"
        xp, m0 = _pad_m(x2, 8)
        out = hadamard.block_hadamard(xp, block=block, interpret=interp)[:m0]
    return out.reshape(lead + (k,))

"""Pallas TPU kernel: causal flash attention (online-softmax, GQA-aware).

The §Perf attribution for glm4-9b x prefill_32k found 4.5 TB/device of f32
score-chain HBM round-trips in the XLA-lowered attention — the score matrix
itself. This kernel is the deployment answer: scores, the running softmax
statistics (m, l) and the output accumulator live in VMEM scratch across
the KV-block loop; HBM traffic reduces to the Q/K/V streams
(FlashAttention adapted to the TPU memory hierarchy: HBM -> VMEM tiles,
MXU for both dots).

Layout: (B*H, S, D) per head-row; GQA maps query head-rows onto shared KV
head-rows inside the BlockSpec index_map (no KV repeat materialization —
the fix the repeated-KV XLA path couldn't express). Grid is
(heads, q_blocks, kv_blocks) with the kv axis innermost ("arbitrary") so
the VMEM accumulators carry across it; fully-masked causal blocks are
skipped with pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, scale: float, causal: bool, window: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * bq
    k_start = j * bk
    # causal: skip blocks entirely in the future; window: entirely expired
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal or window:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = kpos <= qpos if causal else jnp.full((bq, bk), True)
            if window:
                mask = jnp.logical_and(mask, kpos > qpos - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "hper", "interpret"))
def flash_attention_rows(q, k, v, *, hper: int = 1, causal: bool = True,
                         window: int = 0, bq: int = 512, bk: int = 512,
                         interpret: bool = False):
    """q: (Hq_rows, S, D); k,v: (Hkv_rows, T, D) with Hq_rows = Hkv_rows *
    hper (head-major packing of (B, H): row b*H + h). Returns (Hq_rows, S, D).
    """
    hq, s, d = q.shape
    hkv, t, _ = k.shape
    assert hq == hkv * hper, (q.shape, k.shape, hper)
    bq = min(bq, s)
    bk = min(bk, t)
    while s % bq:
        bq //= 2
    while t % bk:
        bk //= 2
    scale = 1.0 / (d ** 0.5)
    grid = (hq, s // bq, t // bk)
    kern = functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                             causal=causal, window=window)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda h, i, j, hper=hper: (h // hper, j, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda h, i, j, hper=hper: (h // hper, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512, interpret: bool = False):
    """Convenience wrapper over (B, S, H, D) / (B, T, G, D) GQA layouts."""
    b, s, h, d = q.shape
    _, t, g, _ = k.shape
    hper = h // g
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * g, t, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * g, t, d)
    out = flash_attention_rows(qr, kr, vr, hper=hper, causal=causal,
                               window=window, bq=bq, bk=bk,
                               interpret=interpret)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)

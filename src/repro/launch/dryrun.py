import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --------------------------------------------------------------------------
# Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
# production meshes, record memory_analysis / cost_analysis / collective
# schedule for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).
#
#   python -m repro.launch.dryrun --arch glm4-9b --shape prefill_32k
#   python -m repro.launch.dryrun --all --jobs 4
#
# The two lines above MUST precede any jax import: jax locks the device
# count at first init, and only the dry-run wants 512 placeholder devices.
# --------------------------------------------------------------------------
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_arch
from repro.core.quant import preset, ptq
from repro.models import transformer
from repro.optim import adamw
from repro.roofline import analysis, hlo_cost
from repro.sharding import rules
from repro.train import trainer
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")
RESULTS_DIR = os.path.abspath(RESULTS_DIR)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input (no alloc)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, b: int, s: int, *, labels: bool) -> dict:
    batch = {}
    if cfg.frontend == "embeddings":
        batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
    if cfg.frontend == "tokens+image":
        batch["ctx"] = _sds((b, cfg.n_ctx_tokens, cfg.d_model), jnp.bfloat16)
    if labels:
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def param_specs(cfg, qcfg=None):
    shapes = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    if qcfg is not None:
        shapes = ptq.quantized_param_shapes(shapes, cfg, qcfg)
    return shapes


def input_specs(arch: str, shape_name: str, quant: str = "int8",
                kv_bits: int = 16):
    """All ShapeDtypeStruct inputs for the cell's entry point."""
    cfg = get_arch(arch)
    spec = SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        state = jax.eval_shape(
            lambda: trainer.init_state(jax.random.PRNGKey(0), cfg,
                                       adamw.OptConfig()))
        return {"state": state, "batch": batch_specs(cfg, b, s, labels=True)}
    qcfg = preset(quant)
    params = param_specs(cfg, qcfg)
    if spec.kind == "prefill":
        return {"params": params,
                "batch": batch_specs(cfg, b, s, labels=False)}
    # decode: one new token against caches of seq_len
    caches = jax.eval_shape(
        lambda: transformer.init_caches(
            jax.eval_shape(lambda: transformer.init_params(
                jax.random.PRNGKey(0), cfg)), cfg, b, s, kv_bits))
    tok = (_sds((b, 1, cfg.d_model), jnp.bfloat16)
           if cfg.frontend == "embeddings" else _sds((b,), jnp.int32))
    return {"params": params, "caches": caches, "token": tok,
            "pos": _sds((b,), jnp.int32)}


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _act_shardings(mesh, cfg):
    dp = rules._dp(mesh)
    nm = mesh.shape["model"]
    from repro.models.transformer import padded_vocab
    act_mode = os.environ.get("REPRO_ACT_SPEC", "dm")
    if act_mode == "seq":      # sequence-parallel boundary (Megatron-SP)
        act = P(dp, "model", None)
    else:
        act = P(dp, None, "model") if cfg.d_model % nm == 0 else P(dp)
    vpad = padded_vocab(cfg.vocab)
    logits = P(dp, None, "model") if vpad % nm == 0 else P(dp)
    return {"act": NamedSharding(mesh, act),
            "logits": NamedSharding(mesh, logits),
            "moe": NamedSharding(mesh, P(dp, "model"))}


def auto_n_micro(cfg) -> int:
    """Gradient-accumulation depth for train_4k: bounds per-microbatch
    activation memory (the dominant term for wide models) while the f32
    grad accumulator stays params-sized (2-D sharded)."""
    p = cfg.param_count()
    if cfg.d_model >= 6144 or p > 40e9:
        return 8
    if cfg.d_model >= 4096 or p > 8e9:
        return 4
    return 1


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               quant: str = "int8", strategy: str = "fsdp_tp",
               kv_bits: int = 16, n_micro: int = 0, hlo_path: str = None):
    cfg = get_arch(arch)
    spec = SHAPES[shape_name]
    ok, why = cell_supported(cfg, spec)
    if not ok:
        return {"status": "skipped", "reason": why}

    if not n_micro:
        n_micro = auto_n_micro(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(arch, shape_name, quant, kv_bits)
    with mesh:
        sh = _act_shardings(mesh, cfg)
        if spec.kind == "train":
            step = trainer.make_train_step(cfg, adamw.OptConfig(),
                                           n_micro=n_micro, remat=True,
                                           shardings=sh)
            state_sh = rules.tree_shardings(mesh, specs["state"], "param",
                                            strategy)
            batch_sh = rules.batch_shardings(mesh, specs["batch"])
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)).lower(
                specs["state"], specs["batch"])
        elif spec.kind == "prefill":
            qcfg = preset(quant)

            def fn(params, batch):
                return transformer.prefill(params, batch, cfg,
                                           max_len=spec.seq_len, qcfg=qcfg,
                                           impl="xla", kv_bits=kv_bits,
                                           shardings=sh)

            p_sh = rules.tree_shardings(mesh, specs["params"], "param",
                                        strategy)
            b_sh = rules.batch_shardings(mesh, specs["batch"])
            lowered = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(
                specs["params"], specs["batch"])
        else:  # decode
            qcfg = preset(quant)

            def fn(params, caches, token, pos):
                return transformer.decode_step(params, caches, token, pos,
                                               cfg, qcfg=qcfg, impl="xla")

            p_sh = rules.tree_shardings(mesh, specs["params"], "param",
                                        strategy)
            c_sh = rules.tree_shardings(mesh, specs["caches"], "cache")
            t_sh = rules.batch_shardings(mesh, {"t": specs["token"]})["t"]
            pos_sh = rules.batch_shardings(mesh, {"p": specs["pos"]})["p"]
            lowered = jax.jit(fn,
                              in_shardings=(p_sh, c_sh, t_sh, pos_sh),
                              donate_argnums=(1,)).lower(
                specs["params"], specs["caches"], specs["token"],
                specs["pos"])
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    # Archive the partitioned HLO (walker re-analysis without recompiling).
    hlo_text = compiled.as_text()
    if hlo_path:
        import gzip
        os.makedirs(os.path.dirname(hlo_path), exist_ok=True)
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo_text)
    # Loop-aware walk: XLA cost_analysis counts while (scan) bodies once;
    # the walker multiplies by known_trip_count (flops, bytes, collectives).
    walk = hlo_cost.analyze(hlo_text)
    csum = walk["collectives"]
    mf = analysis.model_flops(cfg, spec.kind, spec.seq_len, spec.global_batch)
    n_chips = 512 if multi_pod else 256
    int8_flops = 0.0
    if spec.kind != "train" and quant in ("int8", "w8a8", "w4a8",
                                          "w4a8-smooth", "w4a8-hadamard"):
        int8_flops = float(mf["linear_fwd_flops"])
    terms = analysis.roofline_terms(
        hlo_flops_per_dev=walk["flops"],
        hlo_bytes_per_dev=walk["bytes"],
        link_bytes_per_dev=float(csum["total_link_bytes"]),
        n_chips=n_chips, int8_linear_flops_global=int8_flops)

    hlo_flops_global = walk["flops"] * n_chips
    return {
        "status": "ok",
        "arch": arch, "shape": shape_name, "kind": spec.kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "n_chips": n_chips,
        "quant": quant if spec.kind != "train" else "bf16",
        "strategy": strategy, "kv_bits": kv_bits,
        "n_micro": n_micro if spec.kind == "train" else None,
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {"flops_per_dev": walk["flops"],
                 "bytes_per_dev": walk["bytes"],
                 "xla_flops_per_dev": float(ca.get("flops", 0.0)),
                 "xla_bytes_per_dev": float(ca.get("bytes accessed", 0.0))},
        "collectives": csum,
        "model_flops": mf,
        "useful_flops_ratio": (mf["model_flops"] / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "roofline": terms,
        "top_bytes": walk.get("top_bytes", []),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def result_path(arch, shape, multi_pod, quant, strategy, kv_bits, tag=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    return os.path.join(
        RESULTS_DIR,
        f"{arch}__{shape}__{mesh}__{quant}__{strategy}__kv{kv_bits}"
        f"{suffix}.json")


def run_one(args) -> int:
    out = result_path(args.arch, args.shape, args.multi_pod, args.quant,
                      args.strategy, args.kv_bits, args.tag)
    if args.cache and os.path.exists(out):
        print(f"[dryrun] cached: {out}")
        return 0
    try:
        hlo_path = out.replace(".json", ".hlo.gz").replace(
            RESULTS_DIR, os.path.join(RESULTS_DIR, "hlo"))
        res = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                         quant=args.quant, strategy=args.strategy,
                         kv_bits=args.kv_bits, n_micro=args.n_micro,
                         hlo_path=hlo_path)
        if args.tag:
            res["tag"] = args.tag
    except Exception as e:  # record failures — they are bugs to fix
        res = {"status": "error", "arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "quant": args.quant, "strategy": args.strategy,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(out, "w") as f:
        json.dump(res, f, indent=1)
    status = res["status"]
    if status == "ok":
        m = res["memory"]["peak_bytes_per_device"] / 2**30
        r = res["roofline"]
        print(f"[dryrun] {args.arch} x {args.shape} ({res['mesh']}, "
              f"{res['quant']}, {args.strategy}): OK "
              f"compile={res['compile_s']}s peak={m:.2f}GiB/dev "
              f"terms(c/m/coll)={r['compute_s']:.4f}/{r['memory_s']:.4f}/"
              f"{r['collective_s']:.4f}s dom={r['dominant']}")
        print(f"  memory_analysis: {res['memory']}")
        print(f"  cost_analysis: {res['cost']}")
    elif status == "skipped":
        print(f"[dryrun] {args.arch} x {args.shape}: SKIP ({res['reason']})")
    else:
        print(f"[dryrun] {args.arch} x {args.shape} "
              f"({'2x16x16' if args.multi_pod else '16x16'}): "
              f"ERROR {res['error']}")
        print(res.get("traceback", "")[-2000:])
    return 0 if status in ("ok", "skipped") else 1


def run_all(args) -> int:
    """Drive every (arch x shape x mesh) as subprocesses (isolation +
    parallelism; each compile gets a fresh XLA)."""
    # per-cell overrides: 90B decode only fits HBM with the int8 KV cache
    kv_override = {("llama32_vision_90b", "decode_32k"): 8}
    jobs = []
    archs = [a for a in ARCH_IDS if not a.startswith("pangu")]
    for arch in archs:
        for shape in SHAPES:
            for mp in (False, True):
                cfg = get_arch(arch)
                ok, _ = cell_supported(cfg, SHAPES[shape])
                quant = "bf16" if SHAPES[shape].kind == "train" else args.quant
                kv = kv_override.get((arch, shape), args.kv_bits)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--quant", quant, "--strategy", args.strategy,
                       "--kv-bits", str(kv)]
                if mp:
                    cmd.append("--multi-pod")
                if args.cache:
                    cmd.append("--cache")
                jobs.append((arch, shape, mp, cmd, ok))

    running, failures, idx = [], 0, 0
    while idx < len(jobs) or running:
        while idx < len(jobs) and len(running) < args.jobs:
            arch, shape, mp, cmd, ok = jobs[idx]
            idx += 1
            p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True)
            running.append((p, arch, shape, mp))
        done = [r for r in running if r[0].poll() is not None]
        for r in done:
            running.remove(r)
            out = r[0].stdout.read()
            sys.stdout.write(out)
            sys.stdout.flush()
            if r[0].returncode != 0:
                failures += 1
        time.sleep(0.5)
    print(f"[dryrun --all] done; {failures} failures")
    return 1 if failures else 0


def reanalyze_all() -> int:
    """Recompute walker-derived costs from archived HLO (no recompiles)."""
    import glob
    import gzip
    n = 0
    for jf in glob.glob(os.path.join(RESULTS_DIR, "*.json")):
        hf = jf.replace(".json", ".hlo.gz").replace(
            RESULTS_DIR, os.path.join(RESULTS_DIR, "hlo"))
        if not os.path.exists(hf):
            continue
        with open(jf) as f:
            res = json.load(f)
        if res.get("status") != "ok":
            continue
        with gzip.open(hf, "rt") as f:
            walk = hlo_cost.analyze(f.read())
        cfg = get_arch(res["arch"])
        mf = res["model_flops"]
        int8_fl = (mf["linear_fwd_flops"] if res["kind"] != "train"
                   and res["quant"] not in ("bf16", "fp16") else 0.0)
        res["cost"]["flops_per_dev"] = walk["flops"]
        res["cost"]["bytes_per_dev"] = walk["bytes"]
        res["collectives"] = walk["collectives"]
        res["roofline"] = analysis.roofline_terms(
            hlo_flops_per_dev=walk["flops"], hlo_bytes_per_dev=walk["bytes"],
            link_bytes_per_dev=float(walk["collectives"]["total_link_bytes"]),
            n_chips=res["n_chips"], int8_linear_flops_global=int8_fl)
        res["useful_flops_ratio"] = (mf["model_flops"]
                                     / (walk["flops"] * res["n_chips"])
                                     if walk["flops"] else 0.0)
        res["top_bytes"] = walk.get("top_bytes", [])
        with open(jf, "w") as f:
            json.dump(res, f, indent=1)
        n += 1
    print(f"[dryrun] reanalyzed {n} cells from archived HLO")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default="int8",
                    choices=["fp16", "bf16", "int8", "w4a8", "w4a8-smooth",
                             "w4a8-smooth-auto", "w4a8-hadamard"])
    ap.add_argument("--strategy", default="fsdp_tp",
                    choices=["fsdp_tp", "ws", "ws2", "tp"])
    ap.add_argument("--kv-bits", type=int, default=16, choices=[8, 16])
    ap.add_argument("--n-micro", type=int, default=0,
                    help="0 = auto (activation-memory-bounded)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--cache", action="store_true",
                    help="skip cells whose result file already exists")
    ap.add_argument("--tag", default="",
                    help="variant tag appended to the result filename "
                         "(perf-iteration bookkeeping)")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute costs from archived HLO, no compiles")
    args = ap.parse_args()
    if args.arch:
        from repro.configs import get_arch as _ga
        args.arch = _ga(args.arch).name     # canonical id for result paths
    if args.quant in ("fp16", "bf16"):
        args.quant = "bf16" if args.quant == "bf16" else "fp16"
    if args.reanalyze:
        sys.exit(reanalyze_all())
    if args.all:
        sys.exit(run_all(args))
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    sys.exit(run_one(args))


if __name__ == "__main__":
    main()

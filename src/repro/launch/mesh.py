"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; `launch/dryrun.py` sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512
    chips as (pod=2, data=16, model=16); the 'pod' axis carries cross-pod
    data parallelism (DCN-class links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — tests/examples."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

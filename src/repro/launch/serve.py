"""Serving launcher: load (or train) a model, PTQ it, serve batched
requests across the three CoT reasoning modes.

    PYTHONPATH=src python -m repro.launch.serve --arch pangu-1b --reduced \
        --quant int8 --requests 8 --max-new 24

Continuous batching over the paged (optionally int8) KV cache:

    PYTHONPATH=src python -m repro.launch.serve --arch pangu-1b --reduced \
        --engine continuous --kv-bits 8 --page-size 16 --max-batch 8
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, reduced
from repro.core.quant import calibrate, preset, ptq
from repro.data import DataConfig, SyntheticLM, make_prompts
from repro.models import transformer
from repro.serving import ContinuousBatchingEngine, ServingEngine


def _validate(ap: argparse.ArgumentParser, args) -> None:
    """Fail fast on invalid flag combinations with a CLI-level error
    (argparse usage + exit 2) instead of tripping an assert or ValueError
    deep inside the engine after model init and PTQ."""
    if args.prefill_mode == "legacy" and args.prefix_cache == "on":
        ap.error("--prefix-cache on requires --prefill-mode chunked "
                 "(one-shot legacy prefill would rewrite shared pages)")
    if args.spec_decode < 0:
        ap.error("--spec-decode must be >= 0")
    if args.spec_decode and args.prefill_mode == "legacy":
        ap.error("--spec-decode requires --prefill-mode chunked (the "
                 "verify step reuses the chunk-attention machinery)")
    if args.spec_decode and args.engine != "continuous":
        ap.error("--spec-decode requires --engine continuous")
    if args.kv_bits == 4 and args.engine != "continuous":
        ap.error("--kv-bits 4 requires --engine continuous (packed-int4 "
                 "KV lives in the paged pool; the dense batch cache "
                 "supports 8/16 only)")
    if args.engine == "continuous":
        if args.chunk_pages < 1:
            ap.error("--chunk-pages must be >= 1")
        if args.chunk_pages * args.page_size > args.max_seq_len:
            ap.error(f"--chunk-pages {args.chunk_pages} x --page-size "
                     f"{args.page_size} exceeds --max-seq-len "
                     f"{args.max_seq_len}")
        if args.prompt_len + args.max_new > args.max_seq_len:
            ap.error(f"--prompt-len {args.prompt_len} + --max-new "
                     f"{args.max_new} exceeds --max-seq-len "
                     f"{args.max_seq_len}; raise --max-seq-len")
    if args.sampler == "temperature":
        if args.temperature <= 0:
            ap.error("--temperature must be > 0")
        if not 0 < args.top_p <= 1:
            ap.error("--top-p must be in (0, 1]")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="int8",
                    choices=["fp16", "int8", "w4a8", "w4a8-smooth",
                             "w4a8-smooth-auto", "w4a8-hadamard"])
    ap.add_argument("--kv-bits", type=int, default=16, choices=[4, 8, 16])
    ap.add_argument("--engine", default="batch",
                    choices=["batch", "continuous"])
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--paged-impl", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=["chunked", "legacy"],
                    help="chunked: batched mixed prefill/decode steps; "
                    "legacy: one-shot prefill per admission")
    ap.add_argument("--chunk-pages", type=int, default=2,
                    help="prefill chunk size in pages (chunked mode)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step token budget across prefill chunks and "
                    "decode lanes (default: one chunk + all decode lanes)")
    ap.add_argument("--prefix-cache", default=None, choices=["on", "off"],
                    help="share quantized prompt pages across requests via "
                    "refcounted page-table entries (chunked mode only; "
                    "default: on for chunked, off for legacy)")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="draft-free speculative decoding: propose up to K "
                    "tokens per sequence per step via n-gram prompt lookup "
                    "and verify them in one batched step (chunked mode "
                    "only; 0 disables)")
    ap.add_argument("--sampler", default="greedy",
                    choices=["greedy", "temperature"],
                    help="token sampler; temperature uses rejection-"
                    "sampling acceptance under --spec-decode")
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="softmax temperature for --sampler temperature")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus filter for --sampler temperature "
                    "(1.0 disables)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained weights (else random init)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mode", default="all",
                    choices=["all", "slow_think", "auto_think", "no_think"])
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    _validate(ap, args)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt_dir:
        params = Checkpointer(args.ckpt_dir).restore(params)
        print(f"[serve] restored params from {args.ckpt_dir}")

    qcfg = preset(args.quant)
    impl = None
    if qcfg is not None:
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=max(32, args.prompt_len),
                          seed=args.seed + 1)
        data = SyntheticLM(dcfg)
        t0 = time.time()
        stats = calibrate.collect_stats(
            params, data.batches(0, args.calib_batches, 4), cfg)
        params = ptq.quantize_model(params, cfg, qcfg, stats)
        impl = "xla"
        print(f"[serve] PTQ ({args.quant}) done in {time.time() - t0:.1f}s; "
              f"calibrated on {args.calib_batches} batches")

    prompts = make_prompts(DataConfig(vocab=cfg.vocab, seq_len=64),
                           args.requests, args.prompt_len)
    if args.engine == "continuous":
        use_cache = (args.prefill_mode == "chunked"
                     if args.prefix_cache is None
                     else args.prefix_cache == "on")
        eng = ContinuousBatchingEngine(
            params, cfg, qcfg=qcfg, impl=impl, kv_bits=args.kv_bits,
            page_size=args.page_size, max_batch=args.max_batch,
            max_seq_len=args.max_seq_len, paged_impl=args.paged_impl,
            prefill_mode=args.prefill_mode, chunk_pages=args.chunk_pages,
            token_budget=args.token_budget, prefix_cache=use_cache,
            spec_decode=args.spec_decode, sampler=args.sampler,
            temperature=args.temperature, top_p=args.top_p, seed=args.seed)
        mode = "slow_think" if args.mode == "all" else args.mode
        t0 = time.time()
        res = eng.run(prompts, mode=mode, max_new=args.max_new)
        dt = time.time() - t0
        total = sum(len(t) for t in res.tokens)
        print(f"[serve] continuous: {args.requests} requests, {total} tokens "
              f"in {dt:.1f}s ({total / dt:.1f} tok/s), "
              f"{res.mixed_steps} mixed + {res.steps_run} decode steps, "
              f"{res.prefill_tokens} prompt tokens chunked, "
              f"{res.evictions} evictions, "
              f"KV {eng.kv_bytes_per_token():.0f} B/token")
        if args.spec_decode:
            st = eng.spec_stats()
            print(f"[serve] speculative: {res.spec_steps} verify steps, "
                  f"acceptance {st['acceptance_rate']:.2f} "
                  f"({res.accepted_tokens}/{res.draft_tokens} proposals)")
        if use_cache:
            st = eng.prefix_cache_stats()
            print(f"[serve] prefix cache: hit rate {st['hit_rate']:.2f} "
                  f"({st['hit_tokens']}/{st['prompt_tokens']} prompt tokens), "
                  f"{st['cached_pages']} cached pages "
                  f"({st['unreferenced_pages']} unreferenced)")
        for i, toks in enumerate(res.tokens[:4]):
            print(f"[serve] req {i}: {len(toks)} tokens: {toks[:16]}")
        return 0

    eng = ServingEngine(params, cfg, qcfg=qcfg, impl=impl,
                        kv_bits=args.kv_bits)
    t0 = time.time()
    if args.mode == "all":
        study = eng.cot_study(prompts, max_new=args.max_new,
                              sampler=args.sampler, seed=args.seed)
        for mode, r in study.items():
            print(f"[serve] mode={mode:11s} mean_len={r['mean_len']:.1f} "
                  f"repetition_rate={r['repetition_rate']:.2f}")
            print(f"        sample: {r['generations'][0][:16]}")
    else:
        res = eng.generate(prompts, max_new=args.max_new, mode=args.mode,
                           sampler=args.sampler, seed=args.seed)
        for i, toks in enumerate(res.tokens):
            print(f"[serve] req {i}: {len(toks)} tokens: {toks[:16]}")
    print(f"[serve] {args.requests} requests in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Serving launcher: load (or train) a model, PTQ it, serve batched
requests across the three CoT reasoning modes.

    PYTHONPATH=src python -m repro.launch.serve --arch pangu-1b --reduced \
        --quant int8 --requests 8 --max-new 24

Continuous batching over the paged (optionally int8) KV cache:

    PYTHONPATH=src python -m repro.launch.serve --arch pangu-1b --reduced \
        --engine continuous --kv-bits 8 --page-size 16 --max-batch 8
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, reduced
from repro.core.quant import calibrate, preset, ptq
from repro.data import DataConfig, SyntheticLM, make_prompts
from repro.models import transformer
from repro.serving import ContinuousBatchingEngine, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="int8",
                    choices=["fp16", "int8", "w4a8", "w4a8-smooth",
                             "w4a8-smooth-auto", "w4a8-hadamard"])
    ap.add_argument("--kv-bits", type=int, default=16, choices=[8, 16])
    ap.add_argument("--engine", default="batch",
                    choices=["batch", "continuous"])
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--paged-impl", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=["chunked", "legacy"],
                    help="chunked: batched mixed prefill/decode steps; "
                    "legacy: one-shot prefill per admission")
    ap.add_argument("--chunk-pages", type=int, default=2,
                    help="prefill chunk size in pages (chunked mode)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step token budget across prefill chunks and "
                    "decode lanes (default: one chunk + all decode lanes)")
    ap.add_argument("--prefix-cache", default="on", choices=["on", "off"],
                    help="share quantized prompt pages across requests via "
                    "refcounted page-table entries (chunked mode only)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained weights (else random init)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mode", default="all",
                    choices=["all", "slow_think", "auto_think", "no_think"])
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt_dir:
        params = Checkpointer(args.ckpt_dir).restore(params)
        print(f"[serve] restored params from {args.ckpt_dir}")

    qcfg = preset(args.quant)
    impl = None
    if qcfg is not None:
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=max(32, args.prompt_len),
                          seed=args.seed + 1)
        data = SyntheticLM(dcfg)
        t0 = time.time()
        stats = calibrate.collect_stats(
            params, data.batches(0, args.calib_batches, 4), cfg)
        params = ptq.quantize_model(params, cfg, qcfg, stats)
        impl = "xla"
        print(f"[serve] PTQ ({args.quant}) done in {time.time() - t0:.1f}s; "
              f"calibrated on {args.calib_batches} batches")

    prompts = make_prompts(DataConfig(vocab=cfg.vocab, seq_len=64),
                           args.requests, args.prompt_len)
    if args.engine == "continuous":
        use_cache = args.prefix_cache == "on"
        if use_cache and args.prefill_mode == "legacy":
            print("[serve] prefix cache requires chunked prefill; "
                  "disabling for --prefill-mode legacy")
            use_cache = False
        eng = ContinuousBatchingEngine(
            params, cfg, qcfg=qcfg, impl=impl, kv_bits=args.kv_bits,
            page_size=args.page_size, max_batch=args.max_batch,
            max_seq_len=args.max_seq_len, paged_impl=args.paged_impl,
            prefill_mode=args.prefill_mode, chunk_pages=args.chunk_pages,
            token_budget=args.token_budget, prefix_cache=use_cache)
        mode = "slow_think" if args.mode == "all" else args.mode
        t0 = time.time()
        res = eng.run(prompts, mode=mode, max_new=args.max_new)
        dt = time.time() - t0
        total = sum(len(t) for t in res.tokens)
        print(f"[serve] continuous: {args.requests} requests, {total} tokens "
              f"in {dt:.1f}s ({total / dt:.1f} tok/s), "
              f"{res.mixed_steps} mixed + {res.steps_run} decode steps, "
              f"{res.prefill_tokens} prompt tokens chunked, "
              f"{res.evictions} evictions, "
              f"KV {eng.kv_bytes_per_token():.0f} B/token")
        if use_cache:
            st = eng.prefix_cache_stats()
            print(f"[serve] prefix cache: hit rate {st['hit_rate']:.2f} "
                  f"({st['hit_tokens']}/{st['prompt_tokens']} prompt tokens), "
                  f"{st['cached_pages']} cached pages "
                  f"({st['unreferenced_pages']} unreferenced)")
        for i, toks in enumerate(res.tokens[:4]):
            print(f"[serve] req {i}: {len(toks)} tokens: {toks[:16]}")
        return 0

    eng = ServingEngine(params, cfg, qcfg=qcfg, impl=impl,
                        kv_bits=args.kv_bits)
    t0 = time.time()
    if args.mode == "all":
        study = eng.cot_study(prompts, max_new=args.max_new)
        for mode, r in study.items():
            print(f"[serve] mode={mode:11s} mean_len={r['mean_len']:.1f} "
                  f"repetition_rate={r['repetition_rate']:.2f}")
            print(f"        sample: {r['generations'][0][:16]}")
    else:
        res = eng.generate(prompts, max_new=args.max_new, mode=args.mode)
        for i, toks in enumerate(res.tokens):
            print(f"[serve] req {i}: {len(toks)} tokens: {toks[:16]}")
    print(f"[serve] {args.requests} requests in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Training launcher: fault-tolerant loop with checkpoint/restart, SIGTERM
preemption handling, deterministic skip-ahead data resume, heartbeats.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1 --resume auto

On real hardware this runs under `jax.distributed.initialize()` with one
process per host and the production mesh (launch/mesh.py); on this CPU
container it runs the same code single-process (mesh (1,1)). All the
fault-tolerance machinery (atomic async checkpoints, elastic reshard-on-
load, preemption barrier) is live either way.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, reduced
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.sharding import rules
from repro.train import trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 gradient all-reduce over the data axis")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    ocfg = adamw.OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                           total_steps=args.steps)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  seed=args.seed))
    mesh = make_host_mesh()
    state = trainer.init_state(jax.random.PRNGKey(args.seed), cfg, ocfg)

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ck and args.resume == "auto" and ck.latest_step() is not None:
        state = ck.restore(state)
        start_step = int(state.step)
        print(f"[train] resumed from step {start_step}")

    step_fn = trainer.make_train_step(
        cfg, ocfg, n_micro=args.n_micro, remat=True,
        mesh=mesh if args.compress_grads else None,
        dp_axes=("data",), compress=args.compress_grads)
    if not args.compress_grads:
        step_fn = jax.jit(step_fn)

    # Preemption: checkpoint + clean exit on SIGTERM (and finish the step).
    preempted = {"flag": False}

    def _on_sigterm(signum, frame):
        preempted["flag"] = True
        print("[train] SIGTERM received -> checkpointing at next boundary")

    signal.signal(signal.SIGTERM, _on_sigterm)

    t_last = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            batch = data.batch(step, args.batch)
            state, metrics = step_fn(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t_last
                t_last = time.time()
                print(f"[train] step={step} loss={float(metrics['loss']):.4f}"
                      f" gnorm={float(metrics['grad_norm']):.3f}"
                      f" lr={float(metrics['lr']):.2e} wall={dt:.1f}s"
                      f" heartbeat={time.time():.0f}")
            if ck and ((step + 1) % args.ckpt_every == 0
                       or preempted["flag"] or step == args.steps - 1):
                ck.save(step + 1, state, blocking=preempted["flag"])
            if preempted["flag"]:
                print(f"[train] preempted; checkpoint at step {step + 1} "
                      f"saved; exiting 0")
                return 0
    if ck:
        ck.wait()
    print(f"[train] done at step {args.steps}; "
          f"final loss {float(metrics['loss']):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Logical sharding rules with divisibility fallback.

Each parameter/cache leaf is matched by path substring to an ordered list of
candidate PartitionSpecs; the first spec where every named dim divides the
leaf's shape is used, else the next, ending at full replication. This is
what lets one rule table drive 10 architectures whose head counts / vocab /
widths are not all divisible by the mesh (e.g. qwen2's 12 heads vs 16-way
model axis: the *flattened* QKV projection output 2048 shards fine; hymba's
vocab 32001 falls back to d-sharded embedding).

Strategies:
  fsdp_tp — training + baseline serving: weights 2-D sharded (reduction or
            vocab dims over the data axes "FSDP", output features over
            "model"); XLA inserts per-layer all-gathers.
  ws      — weight-stationary serving: feature dims sharded over the
            *combined* (data, model) axes, no weight gathering; activations
            all-reduce instead. The §Perf decode hillclimb compares the two.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import keystr


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def fits(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> bool:
    if len(spec) > len(shape):
        return False
    for dim, axis in zip(shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            return False
    return True


def choose_spec(mesh: Mesh, candidates: Sequence[P],
                shape: Tuple[int, ...]) -> P:
    for spec in candidates:
        if fits(mesh, spec, shape):
            return spec
    return P()


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

def _dp(mesh: Mesh):
    """The data-parallel axes present in this mesh ('pod' first if any)."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    return tuple(axes) if len(axes) > 1 else axes[0]


_IN_NAMES = ("wqkv", "wq", "wkv", "w_in", "w_up", "w_qkv", "w_if", "w_bcdt")
_OUT_NAMES = ("wo", "w_out", "w_down")


def _leaf_kind(path: str) -> str:
    """Classify a parameter leaf by its key path."""
    if "['embed']" in path:
        return "embed"
    if "['lm_head']" in path:
        return "head"
    is_expert = "['moe']" in path
    m = re.search(r"\['(w[a-z_]*)'\]\[", path) or \
        re.search(r"\['(w[a-z_]*)'\]$", path)
    name = m.group(1) if m else ""
    if name in _IN_NAMES:
        return "expert_in" if is_expert else "in"
    if name in _OUT_NAMES:
        return "expert_out" if is_expert else "out"
    if "['router']" in path:
        return "router"
    return "other"


def spec_for_param(mesh: Mesh, path: str, shape, strategy: str = "fsdp_tp",
                   ndim_offset: int = 0) -> P:
    """PartitionSpec for one parameter leaf. Handles: fp weights (w),
    quantized payloads (w_q.data — sharded like w; packed K/2 keeps
    divisibility via even shards), scales, smooth vectors, biases."""
    dp = _dp(mesh)
    ws_mode = strategy in ("ws", "ws2", "tp")
    if ws_mode:
        if strategy == "tp":
            feat = "model"               # classic TP: model axis only
        else:
            feat = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.shape)
        IN = [P(None, feat), P()]
        if strategy == "ws":
            OUT = [P(feat, None), P()]   # K-sharded (partial-sum reduce)
            EOUT = [P(None, feat, None), P()]
        else:                            # ws2/tp: N-sharded — no s32
            OUT = [P(None, feat), P()]   # accumulator reduces for int8
            EOUT = [P(None, None, feat), P()]
        EIN = [P(None, None, feat), P()]
        EMB = [P(feat, None), P(None, feat), P()]
        HEAD = [P(None, feat), P()]
        VEC1 = [P(feat), P()]
        COL = [P(None, feat), P()]       # (1|K//g, N)-shaped scales
    else:
        IN = [P(dp, "model"), P(dp, None), P(None, "model"), P()]
        OUT = [P("model", dp), P(None, dp), P("model", None), P()]
        EIN = [P(None, dp, "model"), P(None, dp, None), P()]
        EOUT = [P(None, "model", dp), P(None, None, dp), P()]
        EMB = [P("model", dp), P(None, dp), P(None, "model"), P()]
        HEAD = [P(dp, "model"), P(dp, None), P()]
        VEC1 = [P("model"), P()]
        COL = [P(None, "model"), P()]

    kind = _leaf_kind(path)
    grouped = "['blocks']" in path          # leading scan-group axis
    is_expert = kind.startswith("expert")

    def with_group(specs):
        return [P(None, *s) for s in specs] + [P()] if grouped else specs

    leaf = path.rsplit("[", 1)[-1]
    if ".data" in path or path.endswith(".data") or "data" == leaf.strip("']"):
        pass  # QTensor payload falls through to weight rules below

    if kind == "embed":
        return choose_spec(mesh, EMB, shape)
    if kind == "head":
        return choose_spec(mesh, HEAD, shape)

    # scales / smooth / bias vectors
    if "scale" in path:
        base = COL if not is_expert else [P(None, *s) for s in COL] + [P()]
        return choose_spec(mesh, with_group(base), shape)
    if "smooth" in path:
        base = [P(None)] if not is_expert else [P(None, None)]
        return choose_spec(mesh, with_group(base + [P()]), shape)
    if re.search(r"\['b'\]$", path):
        return choose_spec(mesh, with_group(VEC1 + [P()]), shape)

    if kind in ("in", "expert_in", "out", "expert_out"):
        base = {"in": IN, "out": OUT,
                "expert_in": EIN, "expert_out": EOUT}[kind]
        return choose_spec(mesh, with_group(base), shape)
    if kind == "router":
        return choose_spec(mesh, with_group([P(dp if not ws_mode else None,
                                               None), P()]), shape)
    return P()  # norms, gates, conv, recurrent mats: replicated


def spec_for_cache(mesh: Mesh, path: str, shape) -> P:
    """KV caches / SSM states, laid out (G, B, ...): batch over the dp axes
    and the largest remaining divisible dim over 'model' — for a 32k KV
    cache that is the *sequence* dim (context-parallel cache), for SSM
    states the feature dim. A 90B x 32k x 128-request decode cache only
    fits HBM with both axes sharded."""
    dp = _dp(mesh)
    ndim = len(shape)
    spec = [None] * ndim
    if ndim >= 2 and shape[1] % _axis_size(mesh, dp) == 0:
        spec[1] = dp
    if "model" in mesh.shape and ndim >= 3:
        nm = mesh.shape["model"]
        cands = [(shape[i], i) for i in range(2, ndim)
                 if shape[i] % nm == 0 and shape[i] >= nm]
        if cands:
            spec[max(cands)[1]] = "model"
    return P(*spec)


def tree_shardings(mesh: Mesh, tree, kind: str = "param",
                   strategy: str = "fsdp_tp"):
    """NamedSharding pytree for params ('param') or caches ('cache')."""
    def one(path, leaf):
        p = keystr(path)
        shape = leaf.shape
        if kind == "cache":
            spec = spec_for_cache(mesh, p, shape)
        else:
            spec = spec_for_param(mesh, p, shape, strategy)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def batch_shardings(mesh: Mesh, batch):
    dp = _dp(mesh)
    def one(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % _axis_size(mesh, dp) == 0:
            return NamedSharding(mesh, P(dp))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, batch)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)

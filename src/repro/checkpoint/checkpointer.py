"""Fault-tolerant checkpointing: atomic, async, elastic.

  * Atomic: writes land in `step_XXXXXXXX.tmp/` and are `os.replace`d into
    place; a crash mid-write never corrupts the latest checkpoint.
  * Async: a background thread serializes device arrays fetched at save
    call time (the train loop continues immediately).
  * Elastic reshard-on-load: leaves are stored as *global* arrays with a
    manifest (tree structure, shapes, dtypes); `restore(..., shardings=)`
    re-slices them onto any mesh — restarting 512-chip training on a
    differently-shaped (or degraded, e.g. failed-pod) mesh is a pure load-
    time operation.
  * Preemption: `launch/train.py` installs a SIGTERM handler that calls
    `save(..., blocking=True)` then exits 0 (see MULTI-POD notes).

Leaves are np arrays in an .npz per checkpoint + a JSON manifest. QTensor
leaves flatten through the pytree protocol like everything else.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


class Checkpointer:
    def __init__(self, root: str, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot `tree` at `step`. Device->host fetch happens here
        (consistent snapshot); serialization runs in the background."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        treedef_str = str(treedef)

        def work():
            final = _step_dir(self.root, step)
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, _ARRAYS),
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "treedef": treedef_str,
                "shapes": [list(a.shape) for a in host_leaves],
                "dtypes": [str(a.dtype) for a in host_leaves],
            }
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            with open(os.path.join(self.root, "LATEST.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.root, "LATEST.tmp"),
                       os.path.join(self.root, "LATEST"))
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.root, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                s = int(f.read().strip())
            if os.path.exists(_step_dir(self.root, s)):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `target` (a pytree of arrays or
        ShapeDtypeStructs). `shardings`: optional matching pytree of
        jax.sharding.Sharding for elastic placement on a new mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = _step_dir(self.root, step)
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, _ARRAYS))
        leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        t_leaves, treedef = jax.tree.flatten(target)
        assert len(t_leaves) == len(leaves), \
            f"leaf count mismatch: ckpt {len(leaves)} vs target {len(t_leaves)}"
        for i, (a, t) in enumerate(zip(leaves, t_leaves)):
            assert tuple(a.shape) == tuple(t.shape), \
                f"leaf {i}: ckpt {a.shape} vs target {t.shape}"
        if shardings is not None:
            s_leaves = jax.tree.flatten(shardings)[0]
            leaves = [jax.device_put(a, s) for a, s in zip(leaves, s_leaves)]
        else:
            leaves = [jnp.asarray(a) for a in leaves]
        return jax.tree.unflatten(treedef, leaves)

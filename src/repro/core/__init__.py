"""The paper's primary contribution: the unified low-bit PTQ framework."""

"""Core PTQ library: the paper's contribution as composable JAX modules."""
from repro.core.quant.qtypes import (  # noqa: F401
    QuantConfig, QTensor, INT8, W4A8, W4A8_SMOOTH, W4A8_HADAMARD, FP16,
    PRESETS, preset, quantize_weight, quantize_act, fake_quant,
    pack_int4, unpack_int4, pack_int4_halves, unpack_int4_halves,
)
from repro.core.quant import smooth, hadamard, qlinear  # noqa: F401

"""Quantization data types: configs, quantized tensors, int4 packing.

Implements the paper's symmetric scheme (Eq. 1-2):
    s    = 2*max(|X|) / (2^n - 1)
    Xbar = clamp(round(X / s), qmin(n), qmax(n))

**Canonical clip range.** The repo-wide symmetric range is the *narrow*
one: [qmin(n), qmax(n)] = [-(2^(n-1) - 1), 2^(n-1) - 1], i.e. [-127, 127]
for int8 and [-7, 7] for int4 — the paper-faithful W8A8 weight range. The
grid stays sign-symmetric (dequantization commutes with negation) and
int8 x int8 products keep 1 spare bit of int32 headroom. The two's-
complement storage minimum (-128 / -8) is available as `qmin_storage(n)`
but is *not* a valid quantized value; earlier revisions mixed both ranges
across files, which is exactly the silent-divergence class of bug the
`repro.analysis` checker now rejects (magic-quant-literal rule: all call
sites must go through `qmin(bits)` / `qmax(bits)`).

Weights are quantized per-output-channel (8-bit) or per-group along the
reduction dim (4-bit, group_size=128 default); activations per-token,
dynamically at runtime. All scales are float32.

INT4 storage: two signed nibbles packed per int8 byte along the reduction
(K) axis — byte = (hi << 4) | (lo & 0xF); unpacking uses arithmetic shifts
for sign extension. This mirrors the Atlas A2 packed-weight layout the
paper configures in CATLASS, adapted to TPU VMEM tiles (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

WEIGHT_GRANULARITIES = ("per_tensor", "per_channel", "per_group")
ACT_GRANULARITIES = ("per_tensor", "per_token")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration of a PTQ scheme.

    Presets below cover the paper's four evaluated settings: INT8 (W8A8),
    W4A8 baseline, W4A8+SmoothQuant, W4A8+Hadamard.
    """

    weight_bits: int = 8                # 4 or 8
    act_bits: int = 8                   # 8 or 16 (16 = weight-only)
    weight_granularity: str = "per_channel"
    act_granularity: str = "per_token"
    group_size: int = 128               # for per_group weights (along K)
    smooth: bool = False                # SmoothQuant diagonal scaling
    smooth_alpha: float = 0.5           # paper uses alpha = 0.5
    hadamard: bool = False              # QuaRot-style block rotation
    hadamard_block: int = 128           # block size of the online FWHT
    kv_bits: int = 16                   # 8 => int8, 4 => packed-int4 KV
                                        # cache (beyond-paper)
    symmetric: bool = True              # paper: symmetric only

    def __post_init__(self):
        assert self.weight_bits in (4, 8), self.weight_bits
        assert self.act_bits in (8, 16), self.act_bits
        assert self.weight_granularity in WEIGHT_GRANULARITIES
        assert self.act_granularity in ACT_GRANULARITIES
        assert self.symmetric, "paper evaluates symmetric quantization only"
        if self.weight_bits == 4:
            assert self.weight_granularity in ("per_group", "per_channel")

    @property
    def is_quantized(self) -> bool:
        return self.weight_bits < 16

    @property
    def name(self) -> str:
        tag = f"w{self.weight_bits}a{self.act_bits}"
        if self.smooth:
            tag += "-smooth"
        if self.hadamard:
            tag += "-hadamard"
        return tag


# The paper's four evaluated configurations (Tables 1-2).
FP16 = None  # sentinel: no quantization
INT8 = QuantConfig(weight_bits=8, act_bits=8)
W4A8 = QuantConfig(weight_bits=4, act_bits=8, weight_granularity="per_group")
W4A8_SMOOTH = dataclasses.replace(W4A8, smooth=True)
# smooth_alpha < 0: per-site migration-strength search (smooth.search_alpha)
W4A8_SMOOTH_AUTO = dataclasses.replace(W4A8, smooth=True, smooth_alpha=-1.0)
W4A8_HADAMARD = dataclasses.replace(W4A8, hadamard=True)

PRESETS = {
    "fp16": FP16,
    "bf16": FP16,
    "int8": INT8,
    "w8a8": INT8,
    "w4a8": W4A8,
    "w4a8-smooth": W4A8_SMOOTH,
    "w4a8-smooth-auto": W4A8_SMOOTH_AUTO,
    "w4a8-hadamard": W4A8_HADAMARD,
}


def preset(name: str) -> Optional[QuantConfig]:
    key = name.lower()
    if key not in PRESETS:
        raise KeyError(f"unknown quant preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[key]


# ---------------------------------------------------------------------------
# Scale computation (paper Eq. 2)
# ---------------------------------------------------------------------------

def qmax(bits: int) -> int:
    """Largest quantized value: 2^(n-1) - 1 (127 for int8, 7 for int4)."""
    return 2 ** (bits - 1) - 1


def qmin(bits: int) -> int:
    """Smallest quantized value — the canonical *narrow symmetric* bound
    -(2^(n-1) - 1), NOT the two's-complement storage minimum (see module
    docstring)."""
    return -qmax(bits)


def qmin_storage(bits: int) -> int:
    """Two's-complement storage minimum (-128 for int8). Valid as a storage
    bit pattern only; quantized values are clipped to [qmin, qmax]."""
    return -(2 ** (bits - 1))


def scale_denom(bits: int) -> float:
    """Denominator of the paper's Eq. 2 scale: 2^n - 1 levels."""
    return float(2 ** bits - 1)


def paper_scale(absmax: jax.Array, bits: int) -> jax.Array:
    """s = 2*max|X| / (2^n - 1). Guards zero rows with eps."""
    s = 2.0 * absmax.astype(jnp.float32) / scale_denom(bits)
    return jnp.maximum(s, 1e-8)


# ---------------------------------------------------------------------------
# QTensor pytree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QTensor:
    """A symmetric-quantized tensor.

    data:  int8 storage. If bits == 4, two nibbles are packed per byte along
           axis `pack_axis` (so data.shape[pack_axis] == orig/2).
    scale: float32 broadcastable against the *unpacked* integer data for
           dequantization, except per-group weights where scale has shape
           (K // group_size, N) and dequant is group-blocked.
    """

    data: jax.Array
    scale: jax.Array
    bits: int
    group_size: int = 0           # 0 => not grouped
    pack_axis: int = 0            # axis nibbles were packed along (bits==4)
    orig_dim: int = 0             # unpacked length of pack_axis (bits==4)
    layout: str = "interleave"    # "interleave" | "halves" (kernel layout)

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        from jax.tree_util import GetAttrKey
        children = ((GetAttrKey("data"), self.data),
                    (GetAttrKey("scale"), self.scale))
        return children, (self.bits, self.group_size, self.pack_axis,
                          self.orig_dim, self.layout)

    def tree_flatten(self):
        return (self.data, self.scale), (self.bits, self.group_size,
                                         self.pack_axis, self.orig_dim,
                                         self.layout)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        bits, group_size, pack_axis, orig_dim, layout = aux
        return cls(data, scale, bits, group_size, pack_axis, orig_dim, layout)

    # -- helpers ------------------------------------------------------------
    @property
    def is_packed(self) -> bool:
        return self.bits == 4

    @property
    def shape(self):
        if not self.is_packed:
            return self.data.shape
        s = list(self.data.shape)
        s[self.pack_axis] = self.orig_dim
        return tuple(s)

    def unpacked(self) -> jax.Array:
        """int8 array of logical shape (values in [-8, 7] when bits==4)."""
        if not self.is_packed:
            return self.data
        if self.layout == "halves":
            g = self.group_size or self.orig_dim
            return unpack_int4_halves(self.data, g)
        return unpack_int4(self.data, self.pack_axis, self.orig_dim)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        w = self.unpacked().astype(jnp.float32)
        if self.group_size:
            k, n = w.shape
            g = self.group_size
            w = w.reshape(k // g, g, n) * self.scale[:, None, :]
            w = w.reshape(k, n)
        else:
            w = w * self.scale
        return w.astype(dtype)


# ---------------------------------------------------------------------------
# INT4 packing
# ---------------------------------------------------------------------------

def pack_int4(x: jax.Array, axis: int = 0) -> jax.Array:
    """Pack int8 values in [-8, 7] pairwise along `axis` into bytes."""
    assert x.dtype == jnp.int8
    assert x.shape[axis] % 2 == 0, f"axis {axis} of {x.shape} must be even"
    x = jnp.moveaxis(x, axis, 0)
    lo = x[0::2]
    hi = x[1::2]
    packed = ((hi << 4) | (lo & 0x0F)).astype(jnp.int8)
    return jnp.moveaxis(packed, 0, axis)


def unpack_int4(packed: jax.Array, axis: int = 0, orig_dim: int = 0) -> jax.Array:
    """Inverse of pack_int4 — arithmetic shifts sign-extend the nibbles."""
    assert packed.dtype == jnp.int8
    p = jnp.moveaxis(packed, axis, 0)
    lo = jnp.right_shift(jnp.left_shift(p, 4), 4)   # sign-extended low nibble
    hi = jnp.right_shift(p, 4)                      # arithmetic shift
    out = jnp.stack([lo, hi], axis=1).reshape((-1,) + p.shape[1:])
    if orig_dim:
        out = out[:orig_dim]
    return jnp.moveaxis(out, 0, axis).astype(jnp.int8)


def pack_int4_halves(x: jax.Array, group: int) -> jax.Array:
    """Deployment ("CATLASS-style") packed layout used by the W4A8 kernel.

    Within each `group` of rows along axis 0, packed byte row i holds
    (lo = row i, hi = row i + group/2), so in-kernel unpacking is a plain
    concatenation of two sign-extended halves — no row interleave, which is
    the TPU-sublane-friendly analogue of the paper's custom weight layout.
    x: (K, N) int8 in [-8, 7], K % group == 0 -> (K//2, N) int8.
    """
    assert x.dtype == jnp.int8 and x.ndim == 2
    k, n = x.shape
    assert group % 2 == 0 and k % group == 0, (k, group)
    xg = x.reshape(k // group, group, n)
    lo = xg[:, : group // 2]
    hi = xg[:, group // 2:]
    packed = ((hi << 4) | (lo & 0x0F)).astype(jnp.int8)
    return packed.reshape(k // 2, n)


def unpack_int4_halves(packed: jax.Array, group: int) -> jax.Array:
    """Inverse of pack_int4_halves. packed: (K//2, N) -> (K, N) int8."""
    assert packed.dtype == jnp.int8 and packed.ndim == 2
    k2, n = packed.shape
    g2 = group // 2
    pg = packed.reshape(k2 // g2, g2, n)
    lo = jnp.right_shift(jnp.left_shift(pg, 4), 4)
    hi = jnp.right_shift(pg, 4)
    out = jnp.concatenate([lo, hi], axis=1)  # (K//g, g, N)
    return out.reshape(2 * k2, n).astype(jnp.int8)


def pack_int4_halves_lastdim(x: jax.Array) -> jax.Array:
    """Grouped-halves pack along the *last* axis — the paged KV-pool page
    layout (group == the whole last dim: byte j holds lo = x[..., j],
    hi = x[..., j + D/2]). Unlike the weight-side `pack_int4_halves` the
    packed dtype is uint8, so pool code and kernels can discriminate
    packed-int4 pages from plain int8 pages by dtype alone.
    x: (..., D) int8 in [-8, 7], D even -> (..., D//2) uint8.
    """
    assert x.dtype == jnp.int8
    d = x.shape[-1]
    assert d % 2 == 0, f"last dim {d} must be even to nibble-pack"
    lo = x[..., : d // 2]
    hi = x[..., d // 2:]
    return ((hi << 4) | (lo & 0x0F)).astype(jnp.uint8)


def unpack_int4_halves_lastdim(packed: jax.Array) -> jax.Array:
    """Inverse of pack_int4_halves_lastdim: (..., D//2) uint8 -> (..., D)
    int8. The uint8 -> int8 astype is a same-width reinterpret (XLA integer
    conversions wrap), so the shift-based sign extension sees the stored
    bit pattern unchanged — works identically inside Pallas kernel bodies.
    """
    assert packed.dtype == jnp.uint8
    b = packed.astype(jnp.int8)
    lo = jnp.right_shift(jnp.left_shift(b, 4), 4)
    hi = jnp.right_shift(b, 4)
    return jnp.concatenate([lo, hi], axis=-1)


# ---------------------------------------------------------------------------
# Quantize / dequantize / fake-quant (reference semantics)
# ---------------------------------------------------------------------------

def _reduce_absmax(x: jax.Array, axis, keepdims=True) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=keepdims)


def quantize_weight(w: jax.Array, cfg: QuantConfig) -> QTensor:
    """Quantize a (K, N) weight: per-channel (scale (1,N)) or per-group
    (scale (K//g, N)); 4-bit results are nibble-packed along K."""
    assert w.ndim == 2, f"weights must be (K, N); got {w.shape}"
    k, n = w.shape
    bits = cfg.weight_bits
    if cfg.weight_granularity == "per_group" and bits == 4:
        # Largest group <= cfg.group_size that divides K (e.g. hymba's
        # d=1600 -> 64). Falls back to per-channel when K is too ragged.
        import math
        g = math.gcd(cfg.group_size, k)
        if g < 8 or g % 2:
            cfg = dataclasses.replace(cfg, weight_granularity="per_channel")
            return quantize_weight(w, cfg)
        assert k % g == 0, f"K={k} not divisible by group_size={g}"
        wg = w.reshape(k // g, g, n)
        scale = paper_scale(_reduce_absmax(wg, axis=1, keepdims=False), bits)
        q = jnp.clip(jnp.round(wg / scale[:, None, :]), qmin(bits), qmax(bits))
        q = q.reshape(k, n).astype(jnp.int8)
        return QTensor(pack_int4_halves(q, g), scale, bits, group_size=g,
                       pack_axis=0, orig_dim=k, layout="halves")
    if cfg.weight_granularity == "per_tensor":
        scale = paper_scale(_reduce_absmax(w, axis=None), bits)
    else:  # per_channel over output dim N: reduce K
        scale = paper_scale(_reduce_absmax(w, axis=0, keepdims=True), bits)
    q = jnp.clip(jnp.round(w / scale), qmin(bits), qmax(bits)).astype(jnp.int8)
    if bits == 4:
        return QTensor(pack_int4(q, 0), scale, bits, pack_axis=0, orig_dim=k)
    return QTensor(q, scale, bits)


def quantize_act(x: jax.Array, bits: int = 8,
                 granularity: str = "per_token"):
    """Dynamic activation quantization. x: (..., K). Returns (q, scale) with
    scale shaped (..., 1) for per_token or scalar-like for per_tensor."""
    if granularity == "per_token":
        scale = paper_scale(_reduce_absmax(x, axis=-1), bits)
    else:
        scale = paper_scale(_reduce_absmax(x, axis=None), bits)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 qmin(bits), qmax(bits)).astype(jnp.int8)
    return q, scale


def fake_quant(x: jax.Array, bits: int, axis=None, group_size: int = 0) -> jax.Array:
    """Quantize-dequantize in float — the simulation oracle used by accuracy
    benchmarks (identical rounding semantics to the integer path)."""
    xf = x.astype(jnp.float32)
    if group_size:
        assert x.ndim == 2 and x.shape[0] % group_size == 0
        k, n = x.shape
        xg = xf.reshape(k // group_size, group_size, n)
        scale = paper_scale(_reduce_absmax(xg, axis=1), bits)
        q = jnp.clip(jnp.round(xg / scale), qmin(bits), qmax(bits))
        return (q * scale).reshape(k, n).astype(x.dtype)
    scale = paper_scale(_reduce_absmax(xf, axis=axis), bits)
    q = jnp.clip(jnp.round(xf / scale), qmin(bits), qmax(bits))
    return (q * scale).astype(x.dtype)

"""SmoothQuant diagonal scaling (paper Eq. 3, alpha = 0.5).

    Y = (X S^{-1}) (S W),   s_j = max|X_j|^alpha / max|W_j|^(1-alpha)

The activation-side division is exact in full precision and migrates
quantization difficulty from outlier activation channels into the weights.

We keep the activation-side vector explicit (`act_div`) and fuse it into the
dynamic quantization step at runtime (one multiply per element inside the
quant kernel — see kernels/quantize_act.py). For norm-fed linears the vector
can instead be folded into the preceding RMSNorm gamma at zero runtime cost
(`fold_into_norm`); both paths are numerically identical in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def smooth_scales(act_absmax: jax.Array, w_absmax: jax.Array,
                  alpha: float = 0.5, eps: float = 1e-5) -> jax.Array:
    """Per-input-channel smoothing vector s (shape (K,)).

    act_absmax: calibration per-channel max|X_j| (K,)
    w_absmax:   per-input-channel  max|W_j|     (K,)  (reduced over outputs)
    """
    a = jnp.maximum(act_absmax.astype(jnp.float32), eps)
    w = jnp.maximum(w_absmax.astype(jnp.float32), eps)
    s = jnp.power(a, alpha) / jnp.power(w, 1.0 - alpha)
    # Degenerate channels (both tiny) -> identity.
    s = jnp.where((act_absmax < eps) & (w_absmax < eps), 1.0, s)
    return jnp.maximum(s, eps)


DEFAULT_ALPHA_GRID = tuple(0.3 + 0.05 * i for i in range(13))   # 0.3 .. 0.9


def search_alpha(act_absmax: jax.Array, w_absmax: jax.Array,
                 w: jax.Array, alphas=DEFAULT_ALPHA_GRID,
                 eps: float = 1e-5) -> jax.Array:
    """Per-site migration strength (scalar alpha, pure jnp — vmap/jit safe).

    SmoothQuant's alpha trades activation-channel difficulty against weight
    difficulty; the right value is model-dependent (0.5 for most OPTs, 0.75+
    for models with harder activation outliers). Activation difficulty is the
    channel-absmax flatness max/mean of a/s (per-token dynamic quantization
    sees the cross-channel spread directly). Weight difficulty needs the full
    matrix: per-output-channel quantization absorbs any common scale, so what
    hurts is the spread of *column* absmax after the row scaling S W. We pick
    the grid point minimizing the worse of the two flatness ratios — the
    balance point where neither side dominates the quantizer's range (the
    paper's Fig. 1 claim holds at this tuned alpha, not necessarily at 0.5).

    w: (K, N) the (concatenated) weight(s) consuming this activation.
    """
    a = jnp.maximum(act_absmax.astype(jnp.float32), eps)
    wv = jnp.maximum(w_absmax.astype(jnp.float32), eps)
    wf = w.astype(jnp.float32)

    def objective(alpha):
        s = jnp.power(a, alpha) / jnp.power(wv, 1.0 - alpha)
        act_side = a / s
        fa = jnp.max(act_side) / jnp.mean(act_side)
        col_am = jnp.max(jnp.abs(wf) * s[:, None], axis=0)      # (N,)
        col_am = jnp.maximum(col_am, eps)
        fw = jnp.max(col_am) / jnp.mean(col_am)
        return jnp.maximum(fa, fw)

    grid = jnp.asarray(alphas, jnp.float32)
    return grid[jnp.argmin(jax.vmap(objective)(grid))]


def smooth_scales_auto(act_absmax: jax.Array, w_absmax: jax.Array,
                       w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """smooth_scales with per-site searched migration strength."""
    alpha = search_alpha(act_absmax, w_absmax, w, eps=eps)
    return smooth_scales(act_absmax, w_absmax, alpha=alpha, eps=eps)


def apply_to_weight(w: jax.Array, s: jax.Array) -> jax.Array:
    """W <- S W (rows scaled by s). w: (K, N), s: (K,)."""
    return (w.astype(jnp.float32) * s[:, None]).astype(w.dtype)


def fold_into_norm(gamma: jax.Array, s: jax.Array) -> jax.Array:
    """Fold X -> X/s into the preceding RMSNorm/LayerNorm gain: gamma/s."""
    return (gamma.astype(jnp.float32) / s).astype(gamma.dtype)


def fold_into_prev_linear(w_prev: jax.Array, s: jax.Array) -> jax.Array:
    """Fold X -> X/s into the producing linear's output channels: W[:, j]/s_j.

    Exact for linear producers. For gated MLPs (SwiGLU) fold into the *up*
    branch only: silu(g) * (u / s) scales the product by exactly 1/s.
    """
    return (w_prev.astype(jnp.float32) / s[None, :]).astype(w_prev.dtype)


def fold_into_prev_linear_squared_relu(w_prev: jax.Array, s: jax.Array) -> jax.Array:
    """Squared-ReLU producer (nemotron): relu(y*c)^2 = c^2 relu(y)^2 for c>0,
    so scaling the producing weight by 1/sqrt(s) scales the output by 1/s —
    exact because s > 0."""
    return (w_prev.astype(jnp.float32) / jnp.sqrt(s)[None, :]).astype(w_prev.dtype)

"""Quantized linear layer — the single GEMM entry point for all models.

A linear's params are a plain dict in one of two forms:

  fp:        {"w": (K, N) float [, "b": (N,)]}
  quantized: {"w_q": QTensor [, "b"] [, "smooth": (K,) f32]}

`apply` dispatches on the form, so post-training quantization is a pure
pytree transformation (core/quant/ptq.py) and model code never changes.

Quantized execution pipeline (paper §3.1-3.2):
    x --(/smooth)--(xH block-FWHT)--> per-token int8 (+scale)   [one kernel]
      --> int8/int4 GEMM, int32 accum, fused dequant epilogue   [one kernel]
      --> + bias (fp)

`impl` selects pallas / pallas_interpret / xla; "fake" runs the float
quant-dequant simulation (same rounding semantics) used by the accuracy
benchmarks, where integer GEMM on CPU would be needlessly slow.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import qtypes
from repro.core.quant.qtypes import QuantConfig, QTensor
from repro.core.quant.hadamard import block_hadamard_matmul
from repro.kernels import ops


def init_linear(key, k: int, n: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None) -> dict:
    scale = scale if scale is not None else (1.0 / jnp.sqrt(k))
    p = {"w": (jax.random.normal(key, (k, n), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    return p


def is_quantized(p: dict) -> bool:
    return "w_q" in p


def _fake_forward(p: dict, x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Float simulation: dequantized weights × fake-quantized activations."""
    wq: QTensor = p["w_q"]
    w = wq.dequantize(jnp.float32)
    t = x.astype(jnp.float32)
    if p.get("smooth") is not None:
        t = t / p["smooth"]
    if cfg.hadamard:
        t = block_hadamard_matmul(t, cfg.hadamard_block)
    if cfg.act_bits == 8:
        q, s = qtypes.quantize_act(t, bits=8, granularity=cfg.act_granularity)
        t = q.astype(jnp.float32) * s
    return t @ w


def _int_forward(p: dict, x: jax.Array, cfg: QuantConfig,
                 impl: Optional[str]) -> jax.Array:
    wq: QTensor = p["w_q"]
    if cfg.act_bits == 16:
        # Weight-only: dequantize + fp GEMM (bandwidth-bound decode helper).
        w = wq.dequantize(x.dtype)
        t = x
        if p.get("smooth") is not None:
            t = t / p["smooth"].astype(x.dtype)
        if cfg.hadamard:
            t = block_hadamard_matmul(t, cfg.hadamard_block)
        return jnp.einsum("...k,kn->...n", t, w)

    hb = cfg.hadamard_block if cfg.hadamard else 0
    q, s = ops.quantize_act_dynamic(x, p.get("smooth"), hadamard_block=hb,
                                    impl=impl)
    if wq.bits == 8:
        return ops.int8_matmul(q, wq.data, s, wq.scale,
                               out_dtype=jnp.float32, impl=impl)
    if wq.group_size:
        return ops.w4a8_matmul(q, wq.data, s, wq.scale,
                               group_size=wq.group_size,
                               out_dtype=jnp.float32, impl=impl)
    # ungrouped int4: unpack + int8 GEMM path
    return ops.int8_matmul(q, wq.unpacked(), s, wq.scale,
                           out_dtype=jnp.float32, impl=impl)


def apply(p: dict, x: jax.Array, cfg: Optional[QuantConfig] = None,
          impl: Optional[str] = None) -> jax.Array:
    """Apply a (possibly quantized) linear. Output dtype follows x."""
    if "w" in p:
        y = jnp.einsum("...k,kn->...n", x, p["w"].astype(x.dtype))
    else:
        assert cfg is not None, "quantized params need a QuantConfig"
        if impl == "fake":
            y = _fake_forward(p, x, cfg)
        else:
            y = _int_forward(p, x, cfg, impl)
        y = y.astype(x.dtype)
    if p.get("b") is not None:
        y = y + p["b"].astype(y.dtype)
    return y

"""Hadamard rotation for outlier-free quantization (paper Eq. 4).

    Y = (X H)(H^T W)

with H a normalized (1/sqrt(b)) block-diagonal Sylvester-Hadamard matrix.
Block-diagonal structure (block = 128, matching the MXU tile) keeps the
online activation transform O(K log b) per token via the fast
Walsh-Hadamard butterfly, while the weight side is rotated once offline at
PTQ time. Because Sylvester H is symmetric, H^T = H and the same block
transform is applied to both sides along the reduction axis; the product is
mathematically unchanged in full precision.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np


@lru_cache(maxsize=None)
def _hadamard_np(n: int) -> np.ndarray:
    assert n & (n - 1) == 0 and n > 0, f"Hadamard size must be a power of 2: {n}"
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h / np.sqrt(n)


def hadamard_matrix(n: int) -> jax.Array:
    """Normalized symmetric orthogonal Hadamard matrix (Sylvester)."""
    return jnp.asarray(_hadamard_np(n))


def block_size_for(k: int, preferred: int = 128) -> int:
    """Largest power-of-two block <= preferred that divides K."""
    b = preferred
    while b > 1 and k % b != 0:
        b //= 2
    return b


def block_hadamard_matmul(x: jax.Array, block: int) -> jax.Array:
    """Apply block-diagonal H along the last axis via explicit matmul
    (dense reference; the Pallas kernel + FWHT below are the fast paths)."""
    k = x.shape[-1]
    b = block_size_for(k, block)
    h = hadamard_matrix(b).astype(jnp.float32)
    xs = x.astype(jnp.float32).reshape(x.shape[:-1] + (k // b, b))
    out = jnp.einsum("...gb,bc->...gc", xs, h)
    return out.reshape(x.shape).astype(x.dtype)


def block_fwht(x: jax.Array, block: int) -> jax.Array:
    """Fast Walsh-Hadamard transform on contiguous `block`-sized groups of
    the last axis. O(K log block) — the online rotation used at serve time."""
    k = x.shape[-1]
    b = block_size_for(k, block)
    xs = x.astype(jnp.float32).reshape(x.shape[:-1] + (k // b, b))
    h = 1
    while h < b:
        xs = xs.reshape(x.shape[:-1] + (k // b, b // (2 * h), 2, h))
        a = xs[..., 0, :]
        c = xs[..., 1, :]
        xs = jnp.concatenate([a + c, a - c], axis=-1)
        h *= 2
    xs = xs.reshape(x.shape[:-1] + (k,)) / jnp.sqrt(jnp.float32(b))
    return xs.astype(x.dtype)


def rotate_weight(w: jax.Array, block: int = 128) -> jax.Array:
    """Offline weight-side rotation: W' = H^T W = H W (block-diagonal along K)."""
    assert w.ndim == 2
    return block_hadamard_matmul(w.T, block).T  # rotate along K (axis 0)

"""Calibration: collect per-channel activation absmax over a calibration set.

The paper calibrates scales on downstream task data (§4.1). Here the model's
forward pass carries a `Taps` accumulator; every quantizable linear records
the absmax of its input channels. Stats are max-merged across calibration
batches and keyed `"{pattern_idx}/{site}"` with a leading per-group axis
(G, K) matching the scan-stacked parameters.
"""
from __future__ import annotations

from typing import Dict, Iterable

import jax
import jax.numpy as jnp

from repro.models import transformer


def collect_stats(params, batches: Iterable[dict], cfg, *,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Run `batches` through the fp model, return merged tap stats."""
    @jax.jit
    def one(p, b):
        _, aux = transformer.forward_train(p, b, cfg, collect_taps=True,
                                           remat=False, dtype=dtype)
        return aux["taps"]

    merged: Dict[str, jax.Array] = {}
    for b in batches:
        taps = one(params, b)
        for k, v in taps.items():
            merged[k] = v if k not in merged else jnp.maximum(merged[k], v)
    assert merged, "calibration produced no taps"
    return jax.tree.map(jax.device_get, merged)

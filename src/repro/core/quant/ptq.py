"""Model-level post-training quantization: a pure pytree transformation.

    params_fp --(calib stats, QuantConfig)--> params_q

For every quant site declared by the block registry (transformer.BLOCKS):
  1. optional SmoothQuant: s from calibrated activation absmax and the
     *combined* weight absmax of all linears sharing that input (fused QKV /
     gate-up share one vector, as SmoothQuant prescribes for fused GEMMs);
  2. optional Hadamard: offline weight-side rotation H^T W;
  3. symmetric weight quantization (per-channel int8 / per-group int4).

Stacked parameter axes (scan groups G, experts E) are handled by nested
vmap — per-group-element and per-expert scales come out naturally. The
result runs through the exact same model code (qlinear dispatch).

Because the transformation is pure jnp, `jax.eval_shape(quantize_model, …)`
yields the quantized parameter ShapeDtypeStructs for the dry-run without
materializing anything.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import hadamard, smooth
from repro.core.quant.qtypes import QuantConfig, quantize_weight
from repro.models.transformer import BLOCKS


def _get_path(tree: dict, path: str) -> dict:
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def _set_path(tree: dict, path: str, value) -> dict:
    parts = path.split("/")
    node = tree
    for part in parts[:-1]:
        node = node[part]
    node[parts[-1]] = value
    return tree


def _w_absmax_per_in(w: jax.Array) -> jax.Array:
    """|w| reduced to (G, K): max over output channels and expert dims."""
    red = tuple(i for i in range(w.ndim) if i not in (0, w.ndim - 2))
    return jnp.max(jnp.abs(w.astype(jnp.float32)), axis=red)


def _quantize_leaf(w: jax.Array, s: Optional[jax.Array], qcfg: QuantConfig):
    """w: (G, [E,] K, N); s: (G, K) or None -> QTensor pytree (leading dims
    preserved on data/scales)."""

    def q2d(w2, s2):
        if s2 is not None:
            w2 = smooth.apply_to_weight(w2, s2)
        if qcfg.hadamard:
            w2 = hadamard.rotate_weight(w2, qcfg.hadamard_block)
        return quantize_weight(w2.astype(jnp.float32), qcfg)

    if w.ndim == 2:
        return q2d(w, s)
    if w.ndim == 3:
        if s is None:
            return jax.vmap(lambda a: q2d(a, None))(w)
        return jax.vmap(q2d)(w, s)
    if w.ndim == 4:  # (G, E, K, N), s (G, K) shared across experts
        if s is None:
            return jax.vmap(jax.vmap(lambda a: q2d(a, None)))(w)
        return jax.vmap(lambda we, sg: jax.vmap(lambda a: q2d(a, sg))(we))(w, s)
    raise ValueError(f"unsupported weight ndim {w.ndim}")


def quantize_model(params: dict, cfg, qcfg: QuantConfig,
                   stats: Optional[Dict[str, jax.Array]] = None) -> dict:
    """cfg: ArchConfig; stats: calibration taps {"i/site": (G, K)} — required
    when qcfg.smooth. Embeddings / norms / router / lm_head stay fp."""
    if qcfg is None:
        return params
    if qcfg.smooth and stats is None:
        raise ValueError("SmoothQuant needs calibration stats")

    out = jax.tree.map(lambda x: x, params)  # structural copy
    for i, btype in enumerate(cfg.pattern):
        sites = BLOCKS[btype].quant_sites
        bp = out["blocks"][str(i)]
        for tap, paths in sites.items():
            leaves = [_get_path(bp, pth) for pth in paths]
            ws = [leaf["w"] for leaf in leaves]
            k_dim = ws[0].shape[-2]
            if k_dim % 2 and qcfg.weight_bits == 4:
                continue  # unpackable; keep fp (not hit by assigned archs)
            s = None
            if qcfg.smooth:
                act_am = jnp.asarray(stats[f"{i}/{tap}"])      # (G, K)
                w_am = jnp.max(jnp.stack([_w_absmax_per_in(w) for w in ws]), 0)
                if qcfg.smooth_alpha < 0:      # sentinel: per-site search
                    # alpha search needs the consuming weight matrix; fold
                    # expert dims into N and concat all sharing linears.
                    w_full = jnp.concatenate(
                        [jnp.moveaxis(w, 1, 2).reshape(
                            w.shape[0], w.shape[2], -1) if w.ndim == 4 else w
                         for w in ws], axis=-1)
                    s = jax.vmap(smooth.smooth_scales_auto)(
                        act_am, w_am, w_full)
                else:
                    s = jax.vmap(partial(smooth.smooth_scales,
                                         alpha=qcfg.smooth_alpha))(act_am, w_am)
            for pth, leaf in zip(paths, leaves):
                new_leaf = {k: v for k, v in leaf.items() if k != "w"}
                new_leaf["w_q"] = _quantize_leaf(leaf["w"], s, qcfg)
                s_leaf = s
                if s is not None and leaf["w"].ndim == 4:
                    # experts: tile the shared smooth vector over E so the
                    # per-expert vmap in moe._expert_ffn sees matching axes
                    g, e, k, _ = leaf["w"].shape
                    s_leaf = jnp.broadcast_to(s[:, None, :], (g, e, k))
                new_leaf["smooth"] = s_leaf if qcfg.smooth else None
                _set_path(bp, pth, new_leaf)
    return out


def quantized_param_shapes(params_shapes, cfg, qcfg: QuantConfig,
                           stats_shapes=None):
    """AOT: ShapeDtypeStructs of the PTQ'd tree (used by launch/dryrun.py)."""
    if qcfg is None:
        return params_shapes
    if qcfg.smooth and stats_shapes is None:
        stats_shapes = synthetic_stats_shapes(params_shapes, cfg)
    return jax.eval_shape(lambda p, s: quantize_model(p, cfg, qcfg, s),
                          params_shapes, stats_shapes)


def synthetic_stats_shapes(params_shapes, cfg):
    """Stats ShapeDtypeStructs (G, K) per site, derived from param shapes."""
    stats = {}
    for i, btype in enumerate(cfg.pattern):
        for tap, paths in BLOCKS[btype].quant_sites.items():
            w = _get_path(params_shapes["blocks"][str(i)], paths[0])["w"]
            g, k = w.shape[0], w.shape[-2]
            stats[f"{i}/{tap}"] = jax.ShapeDtypeStruct((g, k), jnp.float32)
    return stats


def synthetic_stats(params, cfg, value: float = 1.0):
    """Constant stats (for tests / no-calib smoothing baselines)."""
    shapes = synthetic_stats_shapes(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
        cfg)
    return {k: jnp.full(v.shape, value, v.dtype) for k, v in shapes.items()}

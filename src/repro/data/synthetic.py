"""Deterministic synthetic LM data: stateless, index-addressable, resumable.

Every batch is a pure function of (seed, step) — checkpoint restart resumes
mid-epoch with exact skip-ahead and zero replay drift, and every data-
parallel worker can slice its shard deterministically (the property a
1000-node pipeline needs; DESIGN.md §6).

The token stream is a fixed random first-order Markov chain with a low-
entropy transition structure plus periodic copy segments: learnable by a
tiny model in a few hundred steps (perplexity drops well below unigram),
which gives the PTQ fidelity benchmarks a *trained*, non-random subject.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    seed: int = 0
    branching: int = 4          # out-degree of the Markov chain
    copy_period: int = 16       # every k-th token starts a 4-token copy


class SyntheticLM:
    """Markov-chain token stream. `batch(step, b)` is pure in (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # successor table: vocab x branching
        self.succ = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(cfg.vocab, cfg.branching)),
            jnp.int32)

    def batch(self, step: int, batch_size: int, *, host_id: int = 0,
              num_hosts: int = 1) -> dict:
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.seed + 1),
            step * num_hosts + host_id)
        return _gen_batch(key, self.succ, batch_size, self.cfg.seq_len,
                          self.cfg.vocab, self.cfg.branching)

    def batches(self, start_step: int, n: int, batch_size: int, **kw):
        for s in range(start_step, start_step + n):
            yield self.batch(s, batch_size, **kw)


def _gen_batch(key, succ, b, s, vocab, branching):
    k1, k2 = jax.random.split(key)
    first = jax.random.randint(k1, (b,), 0, vocab)
    choices = jax.random.randint(k2, (b, s), 0, branching)

    def step(tok, ch):
        nxt = succ[tok, ch]
        return nxt, nxt

    _, seq = jax.lax.scan(step, first, choices.T)
    tokens = jnp.concatenate([first[:, None], seq.T[:, :-1]], axis=1)
    labels = seq.T
    return {"tokens": tokens, "labels": labels}


def make_prompts(cfg: DataConfig, n: int, prompt_len: int, seed: int = 77):
    """Deterministic prompt list for serving benchmarks."""
    data = SyntheticLM(cfg)
    rng = np.random.default_rng(seed)
    b = data.batch(int(rng.integers(1 << 16)), n)
    return [list(np.asarray(b["tokens"][i, :prompt_len])) for i in range(n)]

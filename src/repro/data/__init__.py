from repro.data.synthetic import DataConfig, SyntheticLM, make_prompts  # noqa
